//! The HierSpec engine: QuantSpec-style hierarchical self-speculation.
//!
//! The dual of QSPEC's design (PAPERS.md, QuantSpec): instead of two
//! *activation* precisions over one cache, one W4A16 module runs both
//! phases and the *KV cache* is the low-precision axis. The draft phase
//! decodes gamma tokens attending over a `kv_bits` quantized shadow of
//! the cache (fast: KV traffic shrinks by 16/kv_bits); the verify phase
//! re-scores all gamma+1 positions attending over full precision and
//! overwrites/requantizes the shadow — the hierarchical analogue of
//! QSPEC's KV-overwriting. No second weight set, no second model: the
//! only extra residency is the shadow tier (kv_bits/16 of the cache).
//!
//! Substrate note: the AOT modules execute in f32, so the shadow tier
//! is *simulated* at the logical layer (`kvcache::QuantizedView`,
//! quantize-on-commit) and the draft's lossiness is injected
//! deterministically: each draft position flips to a wrong token with a
//! probability driven by the shadow's measured round-trip error (so
//! acceptance degrades as `kv_bits` shrinks), while `greedy_accept`
//! guarantees the committed output still equals the verifier's exactly
//! — the losslessness invariant the paper family shares. The cost
//! model prices the draft at quantized-KV bandwidth
//! (`CostModel::charge_kv_bits`), which is where the speedup shows up
//! in benches.
//!
//! Request plumbing lives in the shared [`BatchCore`]; this file is the
//! single-model draft/verify phase logic only. Drafting reuses the
//! W4A16 `decode` entry sequentially (no dedicated fused module is
//! required from the artifact export).

use std::rc::Rc;

use crate::costmodel::{twins::Twin, CostModel, Phase};
use crate::error::Result;
use crate::kvcache::SlotManager;
use crate::metrics::{PhaseKind, PhaseTimer};
use crate::model::tokenizer::PAD;
use crate::model::Mode;
use crate::runtime::{ModelMeta, Module, Session, WeightSet};
use crate::util::prng::Pcg32;

use super::acceptance::{greedy_accept, stochastic_accept};
use super::engine::{BatchCore, Engine, StepBatch};
use super::request::StepEvent;
use super::SimilaritySample;

/// How strongly the shadow tier's mean round-trip error translates into
/// draft-token flips. Calibrated so the acceptance-vs-width curve is
/// QuantSpec-shaped: ~0.99 at 8 bits, ~0.9 at 4 bits (the paper
/// family's operating point), ~0.5 at 2 bits.
const QUANT_FLIP_SENSITIVITY: f32 = 3.0;

/// Flip probability is capped: even a 1-bit shadow still carries signal.
const MAX_FLIP_PROB: f32 = 0.5;

/// Stochastic-path analogue of the flip model: the shadow's round-trip
/// error perturbs the draft *distribution* via deterministic logit-space
/// noise of amplitude `err * QUANT_NOISE_SENSITIVITY` (capped below).
/// Acceptance degrades as `kv_bits` shrinks, exactly like the greedy
/// flip model, but the draft stays a proper q-distribution so the
/// stochastic accept rule keeps the committed stream lossless.
const QUANT_NOISE_SENSITIVITY: f32 = 8.0;

/// Noise amplitude cap: even a 1-bit shadow still carries signal.
const MAX_NOISE_AMP: f32 = 4.0;

/// HierSpec engine configuration.
#[derive(Clone, Debug)]
pub struct HierSpecConfig {
    pub size: String,
    pub scheme: String,
    pub batch: usize,
    /// chain draft length per cycle.
    pub gamma: usize,
    /// shadow-tier storage width the draft attends over (2..=8).
    pub kv_bits: u8,
    /// record fig-2 similarity samples (small overhead).
    pub collect_similarity: bool,
}

impl HierSpecConfig {
    pub fn new(size: &str, batch: usize) -> Self {
        HierSpecConfig {
            size: size.to_string(),
            scheme: "atom".to_string(),
            batch,
            gamma: 3,
            kv_bits: 4,
            collect_similarity: false,
        }
    }
}

/// The engine. One W4A16 module family, one device cache, one weight
/// set; the shadow tier lives in the [`SlotManager`]
/// (`SlotManager::with_shadow`). One `step()` = one scheduling round
/// (admission/prefill then draft+verify).
pub struct HierSpecEngine<'s> {
    #[allow(dead_code)]
    sess: &'s Session,
    pub cfg: HierSpecConfig,
    pub meta: ModelMeta,
    prefill_m: Rc<Module>,
    decode_m: Rc<Module>,
    verify_m: Rc<Module>,
    // logits twins (newer artifact sets only): present => the engine can
    // serve temperature > 0; absent => argmax-only
    prefill_logits_m: Option<Rc<Module>>,
    decode_logits_m: Option<Rc<Module>>,
    verify_logits_m: Option<Rc<Module>>,
    weights: Rc<WeightSet>,
    kv: Option<xla::PjRtBuffer>,
    pub core: BatchCore,
    pub samples: Vec<SimilaritySample>,
}

impl<'s> HierSpecEngine<'s> {
    pub fn new(sess: &'s Session, cfg: HierSpecConfig) -> Result<Self> {
        let meta = sess.store.model(&cfg.size)?.clone();
        let m = &sess.store.manifest;
        let prefill_m = sess.module(&cfg.size, &cfg.scheme, "w4a16", "prefill", cfg.batch, 0)?;
        let decode_m = sess.module(&cfg.size, &cfg.scheme, "w4a16", "decode", cfg.batch, 0)?;
        let verify_m =
            sess.module(&cfg.size, &cfg.scheme, "w4a16", "verify", cfg.batch, cfg.gamma)?;
        let prefill_logits_m = sess
            .module(&cfg.size, &cfg.scheme, "w4a16", "prefill_logits", cfg.batch, 0)
            .ok();
        let decode_logits_m = sess
            .module(&cfg.size, &cfg.scheme, "w4a16", "decode_logits", cfg.batch, 0)
            .ok();
        let verify_logits_m = sess
            .module(&cfg.size, &cfg.scheme, "w4a16", "verify_logits", cfg.batch, cfg.gamma)
            .ok();
        // self-speculation: draft and verify share the one checkpoint
        let weights = sess.weights(&verify_m.meta.weights_key)?;
        let kv = Some(sess.fresh_kv(&cfg.size, cfg.batch)?);
        let slots =
            SlotManager::with_shadow(cfg.batch, meta.max_seq, m.prefill_t, cfg.kv_bits);
        let cost = CostModel::new(Twin::lookup(&meta.paper_twin));

        // virtual-device admission: W4A16 residency plus the shadow
        // tier (kv_bits/16 of the full cache) — still far under the
        // two-model EAGLE footprint
        let resident = cost.weight_bytes(Mode::W4A16)
            + cost.kv_bytes(Mode::W4A16, cfg.batch, 2048)
            + cost.kv_bytes_bits(cfg.kv_bits, cfg.batch, 2048);
        cost.check_memory(resident, "hierspec engine")?;

        Ok(HierSpecEngine {
            sess,
            cfg,
            meta,
            prefill_m,
            decode_m,
            verify_m,
            prefill_logits_m,
            decode_logits_m,
            verify_logits_m,
            weights,
            kv,
            core: BatchCore::new(slots, cost),
            samples: Vec::new(),
        })
    }

    /// Admission + batched prefill (verify precision: full KV + shadow
    /// both written exactly, see `SlotManager::after_prefill`).
    fn admit_and_prefill(&mut self, out: &mut Vec<StepEvent>) -> Result<()> {
        let pb = match self.core.admit_batch(out)? {
            Some(pb) => pb,
            None => return Ok(()),
        };
        let p = self.core.slots.prefill_t();
        let span = self.core.trace.scope("phase.prefill");
        let timer = PhaseTimer::start();
        let kv = self.kv.take().expect("kv");
        let stochastic = pb.admitted.iter().any(|(i, _)| self.core.slot_stochastic(*i));
        let ftok = if stochastic && self.prefill_logits_m.is_some() {
            // logits twin: identical KV writes, first token sampled (or
            // argmax'd for greedy slots) host-side
            let pm = self.prefill_logits_m.clone().expect("prefill_logits");
            let r = pm.call_prefill_logits(&pb.tokens, &pb.start, &pb.mask, &kv, &self.weights)?;
            self.kv = Some(r.kv);
            let vocab = self.meta.vocab;
            let mut tok = vec![PAD; self.cfg.batch];
            for (i, _) in &pb.admitted {
                let row = &r.logits[i * vocab..(i + 1) * vocab];
                tok[*i] = match self.core.sampler_mut(*i) {
                    Some(s) => {
                        let pr = s.probs(row);
                        s.sample_probs(&pr) as i32
                    }
                    None => crate::sampler::argmax(row) as i32,
                };
            }
            tok
        } else {
            let r = self
                .prefill_m
                .call_prefill(&pb.tokens, &pb.start, &pb.mask, &kv, &self.weights)?;
            self.kv = Some(r.kv);
            r.tok
        };
        // prefill is priced per *uncached* token: blocks attached from
        // the prefix cache carry committed KV and cost no compute
        let virt = self
            .core
            .cost
            .charge(Mode::W4A16, Phase::Chunk, pb.admitted.len(), pb.uncached_tokens(), p);
        self.core.metrics.add_phase(PhaseKind::Prefill, timer.elapsed_ns(), virt);
        self.core.finish_prefill(&pb, &ftok, out);
        drop(span);
        Ok(())
    }

    /// Whether the quantized shadow flips draft position `j` of the
    /// slot holding `req_id`: deterministic in (request, position,
    /// step), with probability proportional to the shadow's measured
    /// round-trip error. 4-bit shadows flip rarely; 2-bit often.
    fn quant_flips(&self, req_id: u64, pos: i32, j: usize, err: f32) -> bool {
        let p = (err * QUANT_FLIP_SENSITIVITY).min(MAX_FLIP_PROB);
        if p <= 0.0 {
            return false;
        }
        let seed = (pos as u64) << 8 | j as u64;
        let mut rng = Pcg32::new(seed, req_id.wrapping_mul(2).wrapping_add(1));
        (rng.next_f64() as f32) < p
    }

    /// A wrong-but-in-vocab token for a flipped draft position.
    fn perturb(&self, t: i32, req_id: u64, pos: i32, j: usize) -> i32 {
        let vocab = self.meta.vocab as i32;
        let mut rng = Pcg32::new((pos as u64) << 8 | j as u64, req_id ^ 0x5bd1_e995);
        let off = 1 + (rng.below((vocab - 1).max(1) as u32) as i32);
        (t + off).rem_euclid(vocab)
    }

    /// Stochastic-path shadow lossiness: the quantized attention's
    /// logits, modeled as the exact logits plus deterministic noise in
    /// (request, position, step, vocab entry), amplitude scaled by the
    /// shadow's measured round-trip error. The result is a proper draft
    /// distribution q for the stochastic accept rule (the greedy path
    /// keeps the token-flip model instead).
    fn shadow_noisy_logits(&self, row: &[f32], req_id: u64, pos: i32, j: usize, err: f32) -> Vec<f32> {
        let amp = (err * QUANT_NOISE_SENSITIVITY).min(MAX_NOISE_AMP);
        if amp <= 0.0 {
            return row.to_vec();
        }
        let mut rng = Pcg32::new((pos as u64) << 8 | j as u64, req_id ^ 0xa5a5_a5a5);
        row.iter()
            .map(|&l| l + amp * ((2.0 * rng.next_f64() - 1.0) as f32))
            .collect()
    }

    /// One draft(gamma over the shadow) + verify(gamma+1 over full
    /// precision) + accept cycle over the active slots.
    fn cycle(&mut self, out: &mut Vec<StepEvent>) -> Result<()> {
        let sb = match self.core.step_inputs() {
            Some(sb) => sb,
            None => return Ok(()),
        };
        if self.core.any_stochastic(&sb.active)
            && self.decode_logits_m.is_some()
            && self.verify_logits_m.is_some()
        {
            return self.cycle_stochastic(&sb, out);
        }
        let b = self.cfg.batch;
        let g = self.cfg.gamma;
        let bits = self.cfg.kv_bits;

        // ---- draft phase: gamma sequential W4A16 decode steps over the
        // quantized shadow tier ------------------------------------------
        let span = self.core.trace.scope("phase.draft");
        let timer = PhaseTimer::start();
        let mut kv = self.kv.take().expect("kv");
        let mut cur = sb.tok.clone();
        let mut pos = sb.pos.clone();
        let mut drafts = vec![PAD; b * g];
        let mut draft_probs = vec![0f32; b * g];
        // the shadow's round-trip error only changes at commit, so one
        // O(entries) scan per slot covers the whole cycle
        let mut shadow_err = vec![0f32; b];
        for &i in &sb.active {
            shadow_err[i] = self.core.slots.shadow_error(i);
        }
        let mut virt = 0u128;
        for j in 0..g {
            let r = self.decode_m.call_decode(&cur, &pos, &sb.start, &kv, &self.weights)?;
            kv = r.kv;
            // the draft reads the shadow, not the fp16 cache: charge
            // this step at kv_bits bandwidth — the HierSpec win
            virt += self.core.cost.charge_kv_bits(
                Mode::W4A16,
                Phase::Decode,
                sb.active.len(),
                1,
                sb.mean_ctx,
                bits,
            );
            for &i in &sb.active {
                let req_id = self.core.slots.slot(i).req_id.unwrap_or(0);
                let mut t = r.tok[i];
                if self.quant_flips(req_id, pos[i], j, shadow_err[i]) {
                    // the quantized attention would have argmax'd elsewhere
                    t = self.perturb(t, req_id, pos[i], j);
                }
                drafts[i * g + j] = t;
                draft_probs[i * g + j] = r.prob[i];
                cur[i] = t;
                pos[i] += 1;
            }
        }
        // draft writes land in the shadow tier as speculative entries
        for &i in &sb.active {
            let toks: Vec<i32> = (0..g).map(|j| drafts[i * g + j]).collect();
            self.core.slots.shadow_speculate(i, &toks);
        }
        self.kv = Some(kv);
        self.core.metrics.add_phase(PhaseKind::Draft, timer.elapsed_ns(), virt);
        drop(span);

        // ---- verify phase: one W4A16 parallel chunk over full
        // precision; its KV writes overwrite the draft's entries --------
        let span = self.core.trace.scope("phase.verify");
        let mut vtokens = vec![PAD; b * (g + 1)];
        for slot in 0..b {
            vtokens[slot * (g + 1)] = sb.tok[slot];
            for j in 0..g {
                vtokens[slot * (g + 1) + 1 + j] = drafts[slot * g + j];
            }
        }
        let timer = PhaseTimer::start();
        let kv = self.kv.take().expect("kv");
        let v = self
            .verify_m
            .call_verify(&vtokens, &sb.pos, &sb.start, &sb.mask, &kv, &self.weights)?;
        self.kv = Some(v.kv);
        let virt = self
            .core
            .cost
            .charge(Mode::W4A16, Phase::Chunk, sb.active.len(), g + 1, sb.mean_ctx);
        self.core.metrics.add_phase(PhaseKind::Verify, timer.elapsed_ns(), virt);
        drop(span);

        // ---- acceptance + commit (requantizes the shadow) --------------
        let span = self.core.trace.scope("phase.commit");
        let timer = PhaseTimer::start();
        for &i in &sb.active {
            let dr = &drafts[i * g..(i + 1) * g];
            let vt = &v.vtok[i * (g + 1)..(i + 1) * (g + 1)];
            let dec = greedy_accept(dr, vt);
            self.core.metrics.drafted += g as u64;
            self.core.metrics.accepted += dec.accepted as u64;
            self.core.metrics.record_accept(dec.accepted as u64);
            if self.cfg.collect_similarity {
                for j in 0..g {
                    if self.samples.len() < 100_000 {
                        self.samples.push(SimilaritySample {
                            p_draft: draft_probs[i * g + j],
                            p_verify: v.pfed[i * (g + 1) + j],
                            accepted: j < dec.accepted,
                        });
                    }
                }
            }
            self.core.commit(i, &dec.committed, g, out);
        }
        self.core.metrics.add_phase(PhaseKind::Host, timer.elapsed_ns(), 0);
        drop(span);
        Ok(())
    }

    /// The stochastic cycle: the shadow tier's lossiness becomes a
    /// draft *distribution* q (see [`Self::shadow_noisy_logits`])
    /// rather than a token flip; drafts are sampled from q and the
    /// Leviathan accept rule keeps the committed stream distributed
    /// exactly as the full-precision verifier — the stochastic analogue
    /// of the greedy losslessness invariant. Greedy slots in the same
    /// batch keep the flip model. Cost charges match the greedy cycle
    /// (draft priced at kv_bits bandwidth).
    fn cycle_stochastic(&mut self, sb: &StepBatch, out: &mut Vec<StepEvent>) -> Result<()> {
        let b = self.cfg.batch;
        let g = self.cfg.gamma;
        let bits = self.cfg.kv_bits;
        let vocab = self.meta.vocab;
        let dm = self.decode_logits_m.clone().expect("decode_logits");
        let vm = self.verify_logits_m.clone().expect("verify_logits");

        // ---- draft phase: gamma sequential logits steps over the
        // quantized shadow tier ------------------------------------------
        let span = self.core.trace.scope("phase.draft");
        let timer = PhaseTimer::start();
        let mut cur = sb.tok.clone();
        let mut pos = sb.pos.clone();
        let mut drafts = vec![PAD; b * g];
        let mut q = vec![0f32; b * g * vocab];
        let mut shadow_err = vec![0f32; b];
        for &i in &sb.active {
            shadow_err[i] = self.core.slots.shadow_error(i);
        }
        let mut virt = 0u128;
        for j in 0..g {
            let kv = self.kv.take().expect("kv");
            let r = dm.call_decode_logits(&cur, &pos, &sb.start, &kv, &self.weights)?;
            self.kv = Some(r.kv);
            // the draft reads the shadow, not the fp16 cache: charge
            // this step at kv_bits bandwidth — the HierSpec win
            virt += self.core.cost.charge_kv_bits(
                Mode::W4A16,
                Phase::Decode,
                sb.active.len(),
                1,
                sb.mean_ctx,
                bits,
            );
            for &i in &sb.active {
                let req_id = self.core.slots.slot(i).req_id.unwrap_or(0);
                let row = &r.logits[i * vocab..(i + 1) * vocab];
                let d = if self.core.slot_stochastic(i) {
                    let noisy = self.shadow_noisy_logits(row, req_id, pos[i], j, shadow_err[i]);
                    let s = self.core.sampler_mut(i).expect("sampler");
                    let qp = s.probs(&noisy);
                    let d = s.sample_probs(&qp);
                    let at = (i * g + j) * vocab;
                    q[at..at + vocab].copy_from_slice(&qp);
                    d as i32
                } else {
                    let mut t = crate::sampler::argmax(row) as i32;
                    if self.quant_flips(req_id, pos[i], j, shadow_err[i]) {
                        // the quantized attention would have argmax'd elsewhere
                        t = self.perturb(t, req_id, pos[i], j);
                    }
                    t
                };
                drafts[i * g + j] = d;
                cur[i] = d;
                pos[i] += 1;
            }
        }
        // draft writes land in the shadow tier as speculative entries
        for &i in &sb.active {
            let toks: Vec<i32> = (0..g).map(|j| drafts[i * g + j]).collect();
            self.core.slots.shadow_speculate(i, &toks);
        }
        self.core.metrics.add_phase(PhaseKind::Draft, timer.elapsed_ns(), virt);
        drop(span);

        // ---- verify phase: one parallel chunk over full precision ------
        let span = self.core.trace.scope("phase.verify");
        let mut vtokens = vec![PAD; b * (g + 1)];
        for slot in 0..b {
            vtokens[slot * (g + 1)] = sb.tok[slot];
            for j in 0..g {
                vtokens[slot * (g + 1) + 1 + j] = drafts[slot * g + j];
            }
        }
        let timer = PhaseTimer::start();
        let kv = self.kv.take().expect("kv");
        let v = vm.call_verify_logits(&vtokens, &sb.pos, &sb.start, &sb.mask, &kv, &self.weights)?;
        self.kv = Some(v.kv);
        let virt = self
            .core
            .cost
            .charge(Mode::W4A16, Phase::Chunk, sb.active.len(), g + 1, sb.mean_ctx);
        self.core.metrics.add_phase(PhaseKind::Verify, timer.elapsed_ns(), virt);
        drop(span);

        // ---- acceptance + commit (requantizes the shadow) --------------
        let span = self.core.trace.scope("phase.commit");
        let timer = PhaseTimer::start();
        for &i in &sb.active {
            let dr = &drafts[i * g..(i + 1) * g];
            let vrows = &v.logits[i * (g + 1) * vocab..(i + 1) * (g + 1) * vocab];
            let dec = match self.core.sampler_mut(i) {
                Some(s) => {
                    let mut p = Vec::with_capacity((g + 1) * vocab);
                    for j in 0..=g {
                        p.extend(s.probs(&vrows[j * vocab..(j + 1) * vocab]));
                    }
                    stochastic_accept(dr, &q[i * g * vocab..(i + 1) * g * vocab], &p, vocab, s)
                }
                None => {
                    let vt: Vec<i32> = (0..=g)
                        .map(|j| crate::sampler::argmax(&vrows[j * vocab..(j + 1) * vocab]) as i32)
                        .collect();
                    greedy_accept(dr, &vt)
                }
            };
            self.core.metrics.drafted += g as u64;
            self.core.metrics.accepted += dec.accepted as u64;
            self.core.metrics.record_accept(dec.accepted as u64);
            self.core.commit(i, &dec.committed, g, out);
        }
        self.core.metrics.add_phase(PhaseKind::Host, timer.elapsed_ns(), 0);
        drop(span);
        Ok(())
    }
}

impl<'s> Engine for HierSpecEngine<'s> {
    fn name(&self) -> &'static str {
        "hierspec"
    }

    fn argmax_only(&self) -> bool {
        self.prefill_logits_m.is_none()
            || self.decode_logits_m.is_none()
            || self.verify_logits_m.is_none()
    }

    fn core(&self) -> &BatchCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BatchCore {
        &mut self.core
    }

    fn step(&mut self) -> Result<Vec<StepEvent>> {
        let mut out = Vec::new();
        self.admit_and_prefill(&mut out)?;
        self.cycle(&mut out)?;
        Ok(out)
    }

    fn take_samples(&mut self) -> Vec<SimilaritySample> {
        std::mem::take(&mut self.samples)
    }
}
