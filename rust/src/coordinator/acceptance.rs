//! Acceptance policies for draft-verify speculative decoding.
//!
//! The paper's policy (Sec. 3.1) is greedy top-1 matching: draft token j
//! is accepted iff the verifier's argmax at position j equals it; the
//! first mismatch rejects the tail, and the verifier's own token is
//! emitted in its place (resample). When everything matches, the
//! verifier's extra prediction is appended as a bonus token — so a cycle
//! always commits between 1 and gamma+1 tokens.
//!
//! For `temperature > 0` the greedy rule is not enough: speculative
//! decoding is only *distribution*-lossless under the canonical
//! stochastic accept rule (Leviathan et al.; the mistralrs
//! `SpeculativePipeline` implements the same): accept draft token j
//! with probability `min(1, p_j(x) / q_j(x))` where `q` is the draft
//! distribution the token was actually sampled from and `p` the
//! verifier's distribution at that position; on rejection, resample
//! from the residual `norm(max(0, p_j - q_j))` and drop the tail; when
//! every draft survives, sample the bonus token from `p_gamma`.
//! [`stochastic_accept`] implements this, drawing every random number
//! from the request's seeded [`Sampler`] so replays are exact.

use crate::sampler::Sampler;
use crate::tree::TokenTree;

/// Result of applying an acceptance policy to one slot's cycle.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AcceptDecision {
    /// number of draft tokens accepted (0..=gamma)
    pub accepted: usize,
    /// tokens to commit: accepted drafts + the correction/bonus token
    pub committed: Vec<i32>,
}

/// Greedy top-1 acceptance (the paper's policy).
///
/// * `drafts` — gamma tokens proposed by the W4A4 pass
/// * `verify_argmax` — gamma+1 verifier argmax tokens; position j is the
///   verifier's prediction after seeing the prefix + drafts[..j]
pub fn greedy_accept(drafts: &[i32], verify_argmax: &[i32]) -> AcceptDecision {
    debug_assert_eq!(verify_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if verify_argmax[j] == d {
            committed.push(d);
            accepted += 1;
        } else {
            // rejection: resample from the verify distribution (greedy ->
            // the verifier's own argmax), drop the tail
            committed.push(verify_argmax[j]);
            return AcceptDecision { accepted, committed };
        }
    }
    // all drafts accepted: bonus token from the verifier
    committed.push(verify_argmax[drafts.len()]);
    AcceptDecision { accepted, committed }
}

/// Lenient probability-threshold acceptance (an alternative policy the
/// paper notes is compatible): accept a mismatching draft token if the
/// verifier still assigns it at least `tau` probability. Trades exactness
/// for acceptance rate; not used in headline results.
pub fn threshold_accept(
    drafts: &[i32],
    verify_argmax: &[i32],
    p_fed: &[f32],
    tau: f32,
) -> AcceptDecision {
    debug_assert_eq!(verify_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if verify_argmax[j] == d || p_fed[j] >= tau {
            committed.push(d);
            accepted += 1;
        } else {
            committed.push(verify_argmax[j]);
            return AcceptDecision { accepted, committed };
        }
    }
    committed.push(verify_argmax[drafts.len()]);
    AcceptDecision { accepted, committed }
}

/// Stochastic (distribution-lossless) acceptance — the canonical
/// accept/resample rule for sampled speculative decoding.
///
/// * `drafts` — gamma tokens, token j sampled from `q` row j
/// * `q` — draft distributions, row-major `[gamma, vocab]`: row j is
///   the distribution draft token j was sampled from
/// * `p` — verifier distributions, row-major `[gamma+1, vocab]`: row j
///   is the verifier's distribution after the prefix + drafts[..j]
/// * `sampler` — the request's seeded sampler; consumes one accept
///   draw per considered draft plus exactly one resample/bonus draw
///
/// Per position j: accept draft token `d` with probability
/// `min(1, p_j[d] / q_j[d])`. On rejection, commit a token sampled
/// from the residual `norm(max(0, p_j - q_j))` and stop. If all gamma
/// drafts are accepted, commit a bonus token sampled from `p[gamma]`.
/// The committed stream is then distributed exactly as a pure
/// verifier rollout, whatever `q` was (q only changes *speed*).
///
/// Edge cases: `q_j[d] <= 0` (the draft proposed a token its own
/// distribution says is impossible — numerically degenerate) accepts
/// iff `p_j[d] > 0`; a numerically empty residual (p ≈ q) resamples
/// from `p_j` directly, which is the correct limit.
pub fn stochastic_accept(
    drafts: &[i32],
    q: &[f32],
    p: &[f32],
    vocab: usize,
    sampler: &mut Sampler,
) -> AcceptDecision {
    debug_assert_eq!(q.len(), drafts.len() * vocab);
    debug_assert_eq!(p.len(), (drafts.len() + 1) * vocab);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        let qr = &q[j * vocab..(j + 1) * vocab];
        let pr = &p[j * vocab..(j + 1) * vocab];
        let t = (d as usize).min(vocab.saturating_sub(1));
        let (qd, pd) = (qr[t], pr[t]);
        let ratio = if qd > 0.0 {
            (pd as f64 / qd as f64).min(1.0)
        } else if pd > 0.0 {
            1.0
        } else {
            0.0
        };
        if sampler.accept_draw() < ratio {
            committed.push(d);
            accepted += 1;
            continue;
        }
        // rejection: resample from norm(max(0, p - q)), drop the tail
        let mut residual: Vec<f32> = pr.iter().zip(qr).map(|(&pv, &qv)| (pv - qv).max(0.0)).collect();
        let z: f32 = residual.iter().sum();
        if z > 0.0 && z.is_finite() {
            for r in residual.iter_mut() {
                *r /= z;
            }
            committed.push(sampler.sample_probs(&residual) as i32);
        } else {
            // p == q numerically: the residual is the zero measure and
            // resampling from p itself is the correct limit
            committed.push(sampler.sample_probs(pr) as i32);
        }
        return AcceptDecision { accepted, committed };
    }
    // all drafts accepted: bonus token sampled from p_gamma
    let bonus = &p[drafts.len() * vocab..(drafts.len() + 1) * vocab];
    committed.push(sampler.sample_probs(bonus) as i32);
    AcceptDecision { accepted, committed }
}

/// Result of tree-aware acceptance over one slot's drafted
/// [`TokenTree`] (TreeSpec, protocol v1.7).
///
/// Unlike [`AcceptDecision`], `committed` is *not* always
/// `accepted + 1`: when the accepted root-path ends on a non-principal
/// sibling and no tree-masked verifier row is available for it, no
/// correction/bonus can be produced and `committed == accepted` — the
/// sibling becomes the slot's pending token and the next cycle
/// continues from it (the KV-overwriting design makes that lossless).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TreeAcceptDecision {
    /// number of accepted draft tree nodes (the committed root-path
    /// depth; feeds the `accepted_depth` histogram)
    pub accepted: usize,
    /// tokens to commit: the accepted root-path, plus the
    /// correction/bonus token whenever one could be produced
    pub committed: Vec<i32>,
    /// whether the path ended on a non-principal sibling (a "rescue":
    /// linear acceptance would have rejected at that level)
    pub rescued: bool,
}

/// Greedy tree acceptance: commit the deepest root-path whose every
/// node matches the verifier argmax, plus one correction/bonus token.
///
/// * `tree` — the drafted token tree (principal chain + siblings; all
///   level-`j` nodes share the principal prefix, so one verifier row
///   per level judges them all)
/// * `verify_argmax` — `n_levels + 1` argmax tokens along the
///   principal chain (row `j` = the verifier's prediction after the
///   prefix + principal drafts `[..j]`)
/// * `tree_argmax` — per-node argmax from the tree-masked verify
///   chunk (`tree.len()` entries) when the artifact set exports
///   `verify_tree_logits`; enables a bonus token after a sibling
///   rescue. `None` falls back to ending the path at the sibling.
///
/// The committed stream stays byte-identical to an AR verifier
/// rollout: every committed token is the verifier argmax given the
/// already-committed prefix (a matching sibling *is* the correction
/// token linear acceptance would emit; a sibling bonus comes from the
/// row conditioned on that sibling).
pub fn greedy_tree_accept(
    tree: &TokenTree,
    verify_argmax: &[i32],
    tree_argmax: Option<&[i32]>,
) -> TreeAcceptDecision {
    debug_assert!(tree.n_levels() >= 1);
    debug_assert_eq!(verify_argmax.len(), tree.n_levels() + 1);
    if let Some(t) = tree_argmax {
        debug_assert_eq!(t.len(), tree.len());
    }
    let mut committed = Vec::with_capacity(tree.n_levels() + 1);
    for j in 0..tree.n_levels() {
        let v = verify_argmax[j];
        let lvl = tree.level(j);
        if lvl[0].token == v {
            // principal match: descend the chain
            committed.push(v);
            continue;
        }
        if let Some(k) = lvl.iter().position(|n| n.token == v) {
            // sibling rescue: the matching sibling IS the correction
            // token, and it counts as an accepted draft node
            committed.push(v);
            let accepted = committed.len();
            if let Some(ta) = tree_argmax {
                // bonus from the row conditioned on the sibling
                committed.push(ta[tree.level_range(j).start + k]);
            }
            return TreeAcceptDecision { accepted, committed, rescued: true };
        }
        // no candidate matches: plain correction, drop the tail
        let accepted = committed.len();
        committed.push(v);
        return TreeAcceptDecision { accepted, committed, rescued: false };
    }
    // full principal accept: bonus from the last linear row
    let accepted = committed.len();
    committed.push(verify_argmax[tree.n_levels()]);
    TreeAcceptDecision { accepted, committed, rescued: false }
}

/// Stochastic tree acceptance — SpecInfer-style recursive multi-branch
/// rejection, distribution-lossless for any tree whose level-`j`
/// candidates are i.i.d. draws from the draft distribution `q_j`.
///
/// * `tree` — the drafted token tree; level-`j` candidates are tried
///   in draw order (principal first)
/// * `q` — draft distributions along the principal chain, row-major
///   `[n_levels, vocab]`
/// * `p` — verifier distributions along the principal chain,
///   `[n_levels + 1, vocab]` (row `j` conditions on the principal
///   prefix `[..j]`, which every level-`j` candidate shares)
/// * `tree_p` — per-node verifier rows `[tree.len(), vocab]` from the
///   tree-masked chunk, enabling a bonus draw after a sibling rescue;
///   `None` ends the path at the sibling (still lossless — each
///   committed token's conditional marginal is untouched)
/// * `sampler` — the request's seeded sampler; one accept draw per
///   tried candidate plus at most one resample/bonus draw
///
/// Per level: the residual starts at the verifier row `p_j`; candidate
/// `x` is accepted with probability `min(1, residual[x] / q_j[x])`, and
/// on rejection the *original* `q_j` is subtracted from the residual
/// (clamped at 0, renormalized) before the next sibling is tried —
/// rejected branches' mass is removed exactly once, which is what makes
/// the committed marginal equal the verifier distribution for any
/// number of candidate draws. When every candidate is rejected the
/// level resolves by sampling the final residual (the multi-branch
/// generalization of [`stochastic_accept`]'s rejection resample). A
/// rejected candidate's token always has residual 0 afterwards, so
/// duplicate draws auto-reject and cost only an accept draw.
///
/// At `width == 1` this consumes draws and commits tokens *identically*
/// to [`stochastic_accept`] over the principal chain.
pub fn stochastic_tree_accept(
    tree: &TokenTree,
    q: &[f32],
    p: &[f32],
    tree_p: Option<&[f32]>,
    vocab: usize,
    sampler: &mut Sampler,
) -> TreeAcceptDecision {
    debug_assert!(tree.n_levels() >= 1);
    debug_assert_eq!(q.len(), tree.n_levels() * vocab);
    debug_assert_eq!(p.len(), (tree.n_levels() + 1) * vocab);
    if let Some(t) = tree_p {
        debug_assert_eq!(t.len(), tree.len() * vocab);
    }
    let mut committed = Vec::with_capacity(tree.n_levels() + 1);
    for j in 0..tree.n_levels() {
        let qr = &q[j * vocab..(j + 1) * vocab];
        let pr = &p[j * vocab..(j + 1) * vocab];
        let lvl = tree.level(j);
        let mut residual: Vec<f32> = pr.to_vec();
        let mut winner: Option<usize> = None;
        for (k, node) in lvl.iter().enumerate() {
            let t = (node.token as usize).min(vocab.saturating_sub(1));
            let (qd, rd) = (qr[t], residual[t]);
            let ratio = if qd > 0.0 {
                (rd as f64 / qd as f64).min(1.0)
            } else if rd > 0.0 {
                1.0
            } else {
                0.0
            };
            if sampler.accept_draw() < ratio {
                winner = Some(k);
                break;
            }
            // rejection: subtract this branch's draft distribution from
            // the residual and renormalize before trying the next
            // sibling (the SpecInfer recursion)
            let mut z = 0.0f32;
            for (r, &qv) in residual.iter_mut().zip(qr) {
                *r = (*r - qv).max(0.0);
                z += *r;
            }
            if z > 0.0 && z.is_finite() {
                for r in residual.iter_mut() {
                    *r /= z;
                }
            } else {
                // measure-zero residual (p ≈ q): remaining candidates
                // auto-reject off the zero row; the final resample
                // falls back to p_j below, the correct limit
                for r in residual.iter_mut() {
                    *r = 0.0;
                }
            }
        }
        match winner {
            Some(k) => {
                let node = &lvl[k];
                committed.push(node.token);
                if node.principal {
                    continue; // descend the principal chain
                }
                // sibling rescue: the path ends here (siblings are
                // leaves); a bonus draw needs a row conditioned on
                // the sibling, which only the tree chunk provides
                let accepted = committed.len();
                if let Some(tp) = tree_p {
                    let i = tree.level_range(j).start + k;
                    let row = &tp[i * vocab..(i + 1) * vocab];
                    committed.push(sampler.sample_probs(row) as i32);
                }
                return TreeAcceptDecision { accepted, committed, rescued: true };
            }
            None => {
                // every candidate rejected: resolve the level from the
                // final residual (already normalized), or from p_j in
                // the measure-zero limit
                let z: f32 = residual.iter().sum();
                let accepted = committed.len();
                let tok = if z > 0.0 && z.is_finite() {
                    sampler.sample_probs(&residual)
                } else {
                    sampler.sample_probs(pr)
                };
                committed.push(tok as i32);
                return TreeAcceptDecision { accepted, committed, rescued: false };
            }
        }
    }
    // full principal accept: bonus sampled from the last linear row
    let accepted = committed.len();
    let bonus = &p[tree.n_levels() * vocab..(tree.n_levels() + 1) * vocab];
    committed.push(sampler.sample_probs(bonus) as i32);
    TreeAcceptDecision { accepted, committed, rescued: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;

    fn warm_sampler(seed: u64) -> Sampler {
        Sampler::new(&SamplingParams {
            temperature: 1.0,
            seed,
            ..SamplingParams::default()
        })
    }

    #[test]
    fn all_accepted_appends_bonus() {
        let d = greedy_accept(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(d.accepted, 3);
        assert_eq!(d.committed, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_resamples_and_truncates() {
        let d = greedy_accept(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!(d.accepted, 1);
        assert_eq!(d.committed, vec![5, 9]);
    }

    #[test]
    fn immediate_mismatch_commits_one() {
        let d = greedy_accept(&[5, 6, 7], &[1, 2, 3, 4]);
        assert_eq!(d.accepted, 0);
        assert_eq!(d.committed, vec![1]);
    }

    #[test]
    fn always_commits_at_least_one_at_most_gamma_plus_one() {
        // property: 1 <= committed <= gamma+1; committed == accepted + 1
        use crate::util::check::check;
        use crate::util::prng::Pcg32;
        check(
            "accept-bounds",
            500,
            |r: &mut Pcg32| {
                let g = r.range_inclusive(1, 6) as usize;
                let drafts: Vec<u32> = (0..g).map(|_| r.below(8)).collect();
                let verify: Vec<u32> = (0..g + 1).map(|_| r.below(8)).collect();
                (drafts, verify)
            },
            |(drafts, verify)| {
                let d: Vec<i32> = drafts.iter().map(|&x| x as i32).collect();
                let v: Vec<i32> = verify.iter().map(|&x| x as i32).collect();
                let dec = greedy_accept(&d, &v);
                if dec.committed.len() != dec.accepted + 1 {
                    return Err("committed != accepted+1".into());
                }
                if dec.committed.is_empty() || dec.committed.len() > d.len() + 1 {
                    return Err("bounds".into());
                }
                // accepted prefix must equal both drafts and verify
                for j in 0..dec.accepted {
                    if dec.committed[j] != d[j] || dec.committed[j] != v[j] {
                        return Err("prefix mismatch".into());
                    }
                }
                // the final committed token is always the verifier's
                if *dec.committed.last().unwrap() != v[dec.accepted] {
                    return Err("last token not verifier's".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stochastic_identical_distributions_accept_everything() {
        // q == p => min(1, p/q) == 1 at every position: all drafts
        // accepted, bonus sampled from p_gamma
        let vocab = 4;
        let q = vec![0.25f32; 2 * vocab];
        let p = vec![0.25f32; 3 * vocab];
        for seed in 0..50 {
            let mut s = warm_sampler(seed);
            let dec = stochastic_accept(&[1, 2], &q, &p, vocab, &mut s);
            assert_eq!(dec.accepted, 2);
            assert_eq!(dec.committed.len(), 3);
            assert_eq!(&dec.committed[..2], &[1, 2]);
            assert!((0..vocab as i32).contains(dec.committed.last().unwrap()));
        }
    }

    #[test]
    fn stochastic_impossible_draft_always_rejected() {
        // p assigns zero mass to the draft token: accept prob is 0,
        // and the residual (== p here, since q's mass is elsewhere)
        // never yields that token either
        let vocab = 3;
        let q = vec![0.0f32, 1.0, 0.0]; // draft sampled token 1
        let p = vec![0.5f32, 0.0, 0.5, /* bonus row */ 1.0, 0.0, 0.0];
        for seed in 0..100 {
            let mut s = warm_sampler(seed);
            let dec = stochastic_accept(&[1], &q, &p, vocab, &mut s);
            assert_eq!(dec.accepted, 0);
            assert_eq!(dec.committed.len(), 1);
            assert_ne!(dec.committed[0], 1, "zero-p token resampled");
        }
    }

    #[test]
    fn stochastic_degenerate_q_zero_accepts_when_p_positive() {
        // q[d] == 0 but p[d] > 0: the ratio limit is +inf, clamp to 1
        let vocab = 2;
        let q = vec![1.0f32, 0.0];
        let p = vec![0.0f32, 1.0, 0.5, 0.5];
        let mut s = warm_sampler(7);
        let dec = stochastic_accept(&[1], &q, &p, vocab, &mut s);
        assert_eq!(dec.accepted, 1);
    }

    #[test]
    fn stochastic_same_seed_replays_identically() {
        let vocab = 5;
        let q: Vec<f32> = (0..3 * vocab).map(|i| ((i % 5) as f32 + 1.0) / 15.0).collect();
        let p: Vec<f32> = (0..4 * vocab).map(|i| ((i % 5) as f32 + 1.0) / 15.0).collect();
        let a = stochastic_accept(&[0, 3, 1], &q, &p, vocab, &mut warm_sampler(11));
        let b = stochastic_accept(&[0, 3, 1], &q, &p, vocab, &mut warm_sampler(11));
        assert_eq!(a, b);
        // bounds hold like the greedy rule: 1..=gamma+1 committed
        assert_eq!(a.committed.len(), a.accepted + 1);
    }

    #[test]
    fn threshold_accepts_probable_mismatch() {
        let d = threshold_accept(&[5, 6], &[5, 9, 8], &[0.9, 0.6, 0.1], 0.5);
        assert_eq!(d.accepted, 2);
        assert_eq!(d.committed, vec![5, 6, 8]);
    }

    #[test]
    fn threshold_rejects_improbable_mismatch() {
        let d = threshold_accept(&[5, 6], &[5, 9, 8], &[0.9, 0.2, 0.1], 0.5);
        assert_eq!(d.accepted, 1);
        assert_eq!(d.committed, vec![5, 9]);
    }

    /// width-2 tree: principal chain + one sibling per level.
    fn two_wide_tree(principal: &[i32], siblings: &[i32]) -> TokenTree {
        assert_eq!(principal.len(), siblings.len());
        let mut t = TokenTree::new(2, principal.len());
        for (&p, &s) in principal.iter().zip(siblings) {
            t.push_level(&[(p, 0.5), (s, 0.25)]);
        }
        t
    }

    #[test]
    fn greedy_tree_full_principal_accept_appends_bonus() {
        let t = two_wide_tree(&[5, 6, 7], &[50, 60, 70]);
        let d = greedy_tree_accept(&t, &[5, 6, 7, 8], None);
        assert_eq!(d.accepted, 3);
        assert_eq!(d.committed, vec![5, 6, 7, 8]);
        assert!(!d.rescued);
    }

    #[test]
    fn greedy_tree_sibling_rescue_ends_path() {
        // level 1: principal 6 mismatches but sibling 60 is the argmax —
        // the sibling is committed as an accepted draft node (linear
        // acceptance would emit the same token as a correction and
        // count it rejected)
        let t = two_wide_tree(&[5, 6, 7], &[50, 60, 70]);
        let d = greedy_tree_accept(&t, &[5, 60, 7, 8], None);
        assert_eq!(d.accepted, 2);
        assert_eq!(d.committed, vec![5, 60], "no tree rows: no bonus after a sibling");
        assert!(d.rescued);
        // the committed stream matches linear greedy_accept byte-for-byte
        let lin = greedy_accept(&[5, 6, 7], &[5, 60, 7, 8]);
        assert_eq!(lin.committed, d.committed);
    }

    #[test]
    fn greedy_tree_sibling_bonus_comes_from_tree_row() {
        let t = two_wide_tree(&[5, 6], &[50, 60]);
        // per-node argmax rows: nodes are [5, 50, 6, 60]
        let tree_argmax = vec![100, 101, 102, 103];
        let d = greedy_tree_accept(&t, &[5, 60, 7], Some(&tree_argmax));
        assert_eq!(d.accepted, 2);
        // bonus = the argmax conditioned on sibling 60 (node index 3)
        assert_eq!(d.committed, vec![5, 60, 103]);
        assert!(d.rescued);
    }

    #[test]
    fn greedy_tree_total_mismatch_commits_correction() {
        let t = two_wide_tree(&[5, 6], &[50, 60]);
        let d = greedy_tree_accept(&t, &[9, 6, 7], None);
        assert_eq!(d.accepted, 0);
        assert_eq!(d.committed, vec![9]);
        assert!(!d.rescued);
    }

    #[test]
    fn stochastic_tree_width_one_matches_linear_rule_exactly() {
        // a width-1 tree is the linear chain: the tree rule must
        // consume the same draws and commit the same tokens as
        // stochastic_accept, for any seed
        let vocab = 5;
        let drafts = [0i32, 3, 1];
        let q: Vec<f32> = (0..3 * vocab).map(|i| ((i % 5) as f32 + 1.0) / 15.0).collect();
        let p: Vec<f32> = (0..4 * vocab).map(|i| ((i % 5) as f32 + 1.0) / 15.0).collect();
        for seed in 0..200 {
            let mut t = TokenTree::new(1, 3);
            for (j, &d) in drafts.iter().enumerate() {
                t.push_level(&[(d, q[j * vocab + d as usize])]);
            }
            let lin = stochastic_accept(&drafts, &q, &p, vocab, &mut warm_sampler(seed));
            let tr =
                stochastic_tree_accept(&t, &q, &p, None, vocab, &mut warm_sampler(seed));
            assert_eq!(tr.accepted, lin.accepted, "seed {seed}");
            assert_eq!(tr.committed, lin.committed, "seed {seed}");
        }
    }

    #[test]
    fn stochastic_tree_sibling_rescues_rejected_principal() {
        // principal token 0 has p = 0 (always rejected); after
        // subtracting q the residual is one-hot on the sibling token 1,
        // whose accept ratio is then 1 — deterministic rescue
        let vocab = 4;
        let q = vec![0.5f32, 0.5, 0.0, 0.0];
        let p = vec![0.0f32, 1.0, 0.0, 0.0, /* bonus row */ 0.0, 0.0, 1.0, 0.0];
        for seed in 0..50 {
            let mut t = TokenTree::new(2, 1);
            t.push_level(&[(0, 0.5), (1, 0.5)]);
            let d = stochastic_tree_accept(&t, &q, &p, None, vocab, &mut warm_sampler(seed));
            assert_eq!(d.accepted, 1);
            assert_eq!(d.committed, vec![1], "no tree rows: path ends at the sibling");
            assert!(d.rescued);
            // with tree rows the bonus is drawn from the sibling's row
            let mut t2 = TokenTree::new(2, 1);
            t2.push_level(&[(0, 0.5), (1, 0.5)]);
            // node rows: [0] = principal's, [1] = sibling's (one-hot 3)
            let tree_p = vec![0.25f32, 0.25, 0.25, 0.25, 0.0, 0.0, 0.0, 1.0];
            let d2 = stochastic_tree_accept(
                &t2,
                &q,
                &p,
                Some(&tree_p),
                vocab,
                &mut warm_sampler(seed),
            );
            assert_eq!(d2.committed, vec![1, 3], "bonus from the sibling-conditioned row");
            assert_eq!(d2.accepted, 1);
        }
    }

    #[test]
    fn stochastic_tree_total_rejection_samples_residual() {
        // both candidates carry zero verifier mass: two rejections,
        // then a resample from the residual — which never yields a
        // rejected token
        let vocab = 4;
        let q = vec![0.5f32, 0.5, 0.0, 0.0];
        let p = vec![0.0f32, 0.0, 0.7, 0.3, /* bonus row */ 0.25, 0.25, 0.25, 0.25];
        for seed in 0..100 {
            let mut t = TokenTree::new(2, 1);
            t.push_level(&[(0, 0.5), (1, 0.5)]);
            let d = stochastic_tree_accept(&t, &q, &p, None, vocab, &mut warm_sampler(seed));
            assert_eq!(d.accepted, 0);
            assert_eq!(d.committed.len(), 1);
            assert!(d.committed[0] == 2 || d.committed[0] == 3, "{:?}", d.committed);
            assert!(!d.rescued);
        }
    }

    #[test]
    fn stochastic_tree_duplicate_candidate_auto_rejects() {
        // rejection zeroes the candidate's residual mass, so an i.i.d.
        // duplicate draw can never be accepted afterwards
        let vocab = 3;
        let q = vec![1.0f32, 0.0, 0.0];
        let p = vec![0.0f32, 0.6, 0.4, /* bonus row */ 1.0, 0.0, 0.0];
        for seed in 0..100 {
            let mut t = TokenTree::new(2, 1);
            t.push_level(&[(0, 1.0), (0, 1.0)]);
            let d = stochastic_tree_accept(&t, &q, &p, None, vocab, &mut warm_sampler(seed));
            assert_eq!(d.accepted, 0);
            assert_ne!(d.committed[0], 0, "zero-p token committed");
        }
    }

    #[test]
    fn stochastic_tree_single_level_marginal_matches_verifier() {
        // the committed-token marginal over (draft candidates ~ q) x
        // (accept draws) must equal p exactly — the SpecInfer recursion
        // property, checked empirically at width 3
        let vocab = 4;
        let q = vec![0.4f32, 0.3, 0.2, 0.1];
        let p = vec![0.1f32, 0.2, 0.3, 0.4, /* bonus row */ 0.25, 0.25, 0.25, 0.25];
        let mut counts = [0usize; 4];
        let n = 20_000;
        for seed in 0..n {
            let mut s = warm_sampler(seed as u64);
            // draft: width i.i.d. candidate draws from q (first is the
            // "principal", matching the engine's draft order)
            let mut t = TokenTree::new(3, 1);
            let cands: Vec<(i32, f32)> = (0..3)
                .map(|_| {
                    let c = s.sample_probs(&q);
                    (c as i32, q[c])
                })
                .collect();
            t.push_level(&cands);
            let d = stochastic_tree_accept(&t, &q, &p, None, vocab, &mut s);
            counts[d.committed[0] as usize] += 1;
        }
        for (i, &pi) in p[..vocab].iter().enumerate() {
            let f = counts[i] as f32 / n as f32;
            assert!((f - pi).abs() < 0.02, "bucket {i}: {f} vs {pi}");
        }
    }
}
