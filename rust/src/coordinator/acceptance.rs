//! Acceptance policies for draft-verify speculative decoding.
//!
//! The paper's policy (Sec. 3.1) is greedy top-1 matching: draft token j
//! is accepted iff the verifier's argmax at position j equals it; the
//! first mismatch rejects the tail, and the verifier's own token is
//! emitted in its place (resample). When everything matches, the
//! verifier's extra prediction is appended as a bonus token — so a cycle
//! always commits between 1 and gamma+1 tokens.

/// Result of applying an acceptance policy to one slot's cycle.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AcceptDecision {
    /// number of draft tokens accepted (0..=gamma)
    pub accepted: usize,
    /// tokens to commit: accepted drafts + the correction/bonus token
    pub committed: Vec<i32>,
}

/// Greedy top-1 acceptance (the paper's policy).
///
/// * `drafts` — gamma tokens proposed by the W4A4 pass
/// * `verify_argmax` — gamma+1 verifier argmax tokens; position j is the
///   verifier's prediction after seeing the prefix + drafts[..j]
pub fn greedy_accept(drafts: &[i32], verify_argmax: &[i32]) -> AcceptDecision {
    debug_assert_eq!(verify_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if verify_argmax[j] == d {
            committed.push(d);
            accepted += 1;
        } else {
            // rejection: resample from the verify distribution (greedy ->
            // the verifier's own argmax), drop the tail
            committed.push(verify_argmax[j]);
            return AcceptDecision { accepted, committed };
        }
    }
    // all drafts accepted: bonus token from the verifier
    committed.push(verify_argmax[drafts.len()]);
    AcceptDecision { accepted, committed }
}

/// Lenient probability-threshold acceptance (an alternative policy the
/// paper notes is compatible): accept a mismatching draft token if the
/// verifier still assigns it at least `tau` probability. Trades exactness
/// for acceptance rate; not used in headline results.
pub fn threshold_accept(
    drafts: &[i32],
    verify_argmax: &[i32],
    p_fed: &[f32],
    tau: f32,
) -> AcceptDecision {
    debug_assert_eq!(verify_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if verify_argmax[j] == d || p_fed[j] >= tau {
            committed.push(d);
            accepted += 1;
        } else {
            committed.push(verify_argmax[j]);
            return AcceptDecision { accepted, committed };
        }
    }
    committed.push(verify_argmax[drafts.len()]);
    AcceptDecision { accepted, committed }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_accepted_appends_bonus() {
        let d = greedy_accept(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(d.accepted, 3);
        assert_eq!(d.committed, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_resamples_and_truncates() {
        let d = greedy_accept(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!(d.accepted, 1);
        assert_eq!(d.committed, vec![5, 9]);
    }

    #[test]
    fn immediate_mismatch_commits_one() {
        let d = greedy_accept(&[5, 6, 7], &[1, 2, 3, 4]);
        assert_eq!(d.accepted, 0);
        assert_eq!(d.committed, vec![1]);
    }

    #[test]
    fn always_commits_at_least_one_at_most_gamma_plus_one() {
        // property: 1 <= committed <= gamma+1; committed == accepted + 1
        use crate::util::check::check;
        use crate::util::prng::Pcg32;
        check(
            "accept-bounds",
            500,
            |r: &mut Pcg32| {
                let g = r.range_inclusive(1, 6) as usize;
                let drafts: Vec<u32> = (0..g).map(|_| r.below(8)).collect();
                let verify: Vec<u32> = (0..g + 1).map(|_| r.below(8)).collect();
                (drafts, verify)
            },
            |(drafts, verify)| {
                let d: Vec<i32> = drafts.iter().map(|&x| x as i32).collect();
                let v: Vec<i32> = verify.iter().map(|&x| x as i32).collect();
                let dec = greedy_accept(&d, &v);
                if dec.committed.len() != dec.accepted + 1 {
                    return Err("committed != accepted+1".into());
                }
                if dec.committed.is_empty() || dec.committed.len() > d.len() + 1 {
                    return Err("bounds".into());
                }
                // accepted prefix must equal both drafts and verify
                for j in 0..dec.accepted {
                    if dec.committed[j] != d[j] || dec.committed[j] != v[j] {
                        return Err("prefix mismatch".into());
                    }
                }
                // the final committed token is always the verifier's
                if *dec.committed.last().unwrap() != v[dec.accepted] {
                    return Err("last token not verifier's".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn threshold_accepts_probable_mismatch() {
        let d = threshold_accept(&[5, 6], &[5, 9, 8], &[0.9, 0.6, 0.1], 0.5);
        assert_eq!(d.accepted, 2);
        assert_eq!(d.committed, vec![5, 6, 8]);
    }

    #[test]
    fn threshold_rejects_improbable_mismatch() {
        let d = threshold_accept(&[5, 6], &[5, 9, 8], &[0.9, 0.2, 0.1], 0.5);
        assert_eq!(d.accepted, 1);
        assert_eq!(d.committed, vec![5, 9]);
    }
}
