//! Acceptance policies for draft-verify speculative decoding.
//!
//! The paper's policy (Sec. 3.1) is greedy top-1 matching: draft token j
//! is accepted iff the verifier's argmax at position j equals it; the
//! first mismatch rejects the tail, and the verifier's own token is
//! emitted in its place (resample). When everything matches, the
//! verifier's extra prediction is appended as a bonus token — so a cycle
//! always commits between 1 and gamma+1 tokens.
//!
//! For `temperature > 0` the greedy rule is not enough: speculative
//! decoding is only *distribution*-lossless under the canonical
//! stochastic accept rule (Leviathan et al.; the mistralrs
//! `SpeculativePipeline` implements the same): accept draft token j
//! with probability `min(1, p_j(x) / q_j(x))` where `q` is the draft
//! distribution the token was actually sampled from and `p` the
//! verifier's distribution at that position; on rejection, resample
//! from the residual `norm(max(0, p_j - q_j))` and drop the tail; when
//! every draft survives, sample the bonus token from `p_gamma`.
//! [`stochastic_accept`] implements this, drawing every random number
//! from the request's seeded [`Sampler`] so replays are exact.

use crate::sampler::Sampler;

/// Result of applying an acceptance policy to one slot's cycle.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AcceptDecision {
    /// number of draft tokens accepted (0..=gamma)
    pub accepted: usize,
    /// tokens to commit: accepted drafts + the correction/bonus token
    pub committed: Vec<i32>,
}

/// Greedy top-1 acceptance (the paper's policy).
///
/// * `drafts` — gamma tokens proposed by the W4A4 pass
/// * `verify_argmax` — gamma+1 verifier argmax tokens; position j is the
///   verifier's prediction after seeing the prefix + drafts[..j]
pub fn greedy_accept(drafts: &[i32], verify_argmax: &[i32]) -> AcceptDecision {
    debug_assert_eq!(verify_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if verify_argmax[j] == d {
            committed.push(d);
            accepted += 1;
        } else {
            // rejection: resample from the verify distribution (greedy ->
            // the verifier's own argmax), drop the tail
            committed.push(verify_argmax[j]);
            return AcceptDecision { accepted, committed };
        }
    }
    // all drafts accepted: bonus token from the verifier
    committed.push(verify_argmax[drafts.len()]);
    AcceptDecision { accepted, committed }
}

/// Lenient probability-threshold acceptance (an alternative policy the
/// paper notes is compatible): accept a mismatching draft token if the
/// verifier still assigns it at least `tau` probability. Trades exactness
/// for acceptance rate; not used in headline results.
pub fn threshold_accept(
    drafts: &[i32],
    verify_argmax: &[i32],
    p_fed: &[f32],
    tau: f32,
) -> AcceptDecision {
    debug_assert_eq!(verify_argmax.len(), drafts.len() + 1);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        if verify_argmax[j] == d || p_fed[j] >= tau {
            committed.push(d);
            accepted += 1;
        } else {
            committed.push(verify_argmax[j]);
            return AcceptDecision { accepted, committed };
        }
    }
    committed.push(verify_argmax[drafts.len()]);
    AcceptDecision { accepted, committed }
}

/// Stochastic (distribution-lossless) acceptance — the canonical
/// accept/resample rule for sampled speculative decoding.
///
/// * `drafts` — gamma tokens, token j sampled from `q` row j
/// * `q` — draft distributions, row-major `[gamma, vocab]`: row j is
///   the distribution draft token j was sampled from
/// * `p` — verifier distributions, row-major `[gamma+1, vocab]`: row j
///   is the verifier's distribution after the prefix + drafts[..j]
/// * `sampler` — the request's seeded sampler; consumes one accept
///   draw per considered draft plus exactly one resample/bonus draw
///
/// Per position j: accept draft token `d` with probability
/// `min(1, p_j[d] / q_j[d])`. On rejection, commit a token sampled
/// from the residual `norm(max(0, p_j - q_j))` and stop. If all gamma
/// drafts are accepted, commit a bonus token sampled from `p[gamma]`.
/// The committed stream is then distributed exactly as a pure
/// verifier rollout, whatever `q` was (q only changes *speed*).
///
/// Edge cases: `q_j[d] <= 0` (the draft proposed a token its own
/// distribution says is impossible — numerically degenerate) accepts
/// iff `p_j[d] > 0`; a numerically empty residual (p ≈ q) resamples
/// from `p_j` directly, which is the correct limit.
pub fn stochastic_accept(
    drafts: &[i32],
    q: &[f32],
    p: &[f32],
    vocab: usize,
    sampler: &mut Sampler,
) -> AcceptDecision {
    debug_assert_eq!(q.len(), drafts.len() * vocab);
    debug_assert_eq!(p.len(), (drafts.len() + 1) * vocab);
    let mut committed = Vec::with_capacity(drafts.len() + 1);
    let mut accepted = 0;
    for (j, &d) in drafts.iter().enumerate() {
        let qr = &q[j * vocab..(j + 1) * vocab];
        let pr = &p[j * vocab..(j + 1) * vocab];
        let t = (d as usize).min(vocab.saturating_sub(1));
        let (qd, pd) = (qr[t], pr[t]);
        let ratio = if qd > 0.0 {
            (pd as f64 / qd as f64).min(1.0)
        } else if pd > 0.0 {
            1.0
        } else {
            0.0
        };
        if sampler.accept_draw() < ratio {
            committed.push(d);
            accepted += 1;
            continue;
        }
        // rejection: resample from norm(max(0, p - q)), drop the tail
        let mut residual: Vec<f32> = pr.iter().zip(qr).map(|(&pv, &qv)| (pv - qv).max(0.0)).collect();
        let z: f32 = residual.iter().sum();
        if z > 0.0 && z.is_finite() {
            for r in residual.iter_mut() {
                *r /= z;
            }
            committed.push(sampler.sample_probs(&residual) as i32);
        } else {
            // p == q numerically: the residual is the zero measure and
            // resampling from p itself is the correct limit
            committed.push(sampler.sample_probs(pr) as i32);
        }
        return AcceptDecision { accepted, committed };
    }
    // all drafts accepted: bonus token sampled from p_gamma
    let bonus = &p[drafts.len() * vocab..(drafts.len() + 1) * vocab];
    committed.push(sampler.sample_probs(bonus) as i32);
    AcceptDecision { accepted, committed }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SamplingParams;

    fn warm_sampler(seed: u64) -> Sampler {
        Sampler::new(&SamplingParams {
            temperature: 1.0,
            seed,
            ..SamplingParams::default()
        })
    }

    #[test]
    fn all_accepted_appends_bonus() {
        let d = greedy_accept(&[5, 6, 7], &[5, 6, 7, 8]);
        assert_eq!(d.accepted, 3);
        assert_eq!(d.committed, vec![5, 6, 7, 8]);
    }

    #[test]
    fn first_mismatch_resamples_and_truncates() {
        let d = greedy_accept(&[5, 6, 7], &[5, 9, 7, 8]);
        assert_eq!(d.accepted, 1);
        assert_eq!(d.committed, vec![5, 9]);
    }

    #[test]
    fn immediate_mismatch_commits_one() {
        let d = greedy_accept(&[5, 6, 7], &[1, 2, 3, 4]);
        assert_eq!(d.accepted, 0);
        assert_eq!(d.committed, vec![1]);
    }

    #[test]
    fn always_commits_at_least_one_at_most_gamma_plus_one() {
        // property: 1 <= committed <= gamma+1; committed == accepted + 1
        use crate::util::check::check;
        use crate::util::prng::Pcg32;
        check(
            "accept-bounds",
            500,
            |r: &mut Pcg32| {
                let g = r.range_inclusive(1, 6) as usize;
                let drafts: Vec<u32> = (0..g).map(|_| r.below(8)).collect();
                let verify: Vec<u32> = (0..g + 1).map(|_| r.below(8)).collect();
                (drafts, verify)
            },
            |(drafts, verify)| {
                let d: Vec<i32> = drafts.iter().map(|&x| x as i32).collect();
                let v: Vec<i32> = verify.iter().map(|&x| x as i32).collect();
                let dec = greedy_accept(&d, &v);
                if dec.committed.len() != dec.accepted + 1 {
                    return Err("committed != accepted+1".into());
                }
                if dec.committed.is_empty() || dec.committed.len() > d.len() + 1 {
                    return Err("bounds".into());
                }
                // accepted prefix must equal both drafts and verify
                for j in 0..dec.accepted {
                    if dec.committed[j] != d[j] || dec.committed[j] != v[j] {
                        return Err("prefix mismatch".into());
                    }
                }
                // the final committed token is always the verifier's
                if *dec.committed.last().unwrap() != v[dec.accepted] {
                    return Err("last token not verifier's".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stochastic_identical_distributions_accept_everything() {
        // q == p => min(1, p/q) == 1 at every position: all drafts
        // accepted, bonus sampled from p_gamma
        let vocab = 4;
        let q = vec![0.25f32; 2 * vocab];
        let p = vec![0.25f32; 3 * vocab];
        for seed in 0..50 {
            let mut s = warm_sampler(seed);
            let dec = stochastic_accept(&[1, 2], &q, &p, vocab, &mut s);
            assert_eq!(dec.accepted, 2);
            assert_eq!(dec.committed.len(), 3);
            assert_eq!(&dec.committed[..2], &[1, 2]);
            assert!((0..vocab as i32).contains(dec.committed.last().unwrap()));
        }
    }

    #[test]
    fn stochastic_impossible_draft_always_rejected() {
        // p assigns zero mass to the draft token: accept prob is 0,
        // and the residual (== p here, since q's mass is elsewhere)
        // never yields that token either
        let vocab = 3;
        let q = vec![0.0f32, 1.0, 0.0]; // draft sampled token 1
        let p = vec![0.5f32, 0.0, 0.5, /* bonus row */ 1.0, 0.0, 0.0];
        for seed in 0..100 {
            let mut s = warm_sampler(seed);
            let dec = stochastic_accept(&[1], &q, &p, vocab, &mut s);
            assert_eq!(dec.accepted, 0);
            assert_eq!(dec.committed.len(), 1);
            assert_ne!(dec.committed[0], 1, "zero-p token resampled");
        }
    }

    #[test]
    fn stochastic_degenerate_q_zero_accepts_when_p_positive() {
        // q[d] == 0 but p[d] > 0: the ratio limit is +inf, clamp to 1
        let vocab = 2;
        let q = vec![1.0f32, 0.0];
        let p = vec![0.0f32, 1.0, 0.5, 0.5];
        let mut s = warm_sampler(7);
        let dec = stochastic_accept(&[1], &q, &p, vocab, &mut s);
        assert_eq!(dec.accepted, 1);
    }

    #[test]
    fn stochastic_same_seed_replays_identically() {
        let vocab = 5;
        let q: Vec<f32> = (0..3 * vocab).map(|i| ((i % 5) as f32 + 1.0) / 15.0).collect();
        let p: Vec<f32> = (0..4 * vocab).map(|i| ((i % 5) as f32 + 1.0) / 15.0).collect();
        let a = stochastic_accept(&[0, 3, 1], &q, &p, vocab, &mut warm_sampler(11));
        let b = stochastic_accept(&[0, 3, 1], &q, &p, vocab, &mut warm_sampler(11));
        assert_eq!(a, b);
        // bounds hold like the greedy rule: 1..=gamma+1 committed
        assert_eq!(a.committed.len(), a.accepted + 1);
    }

    #[test]
    fn threshold_accepts_probable_mismatch() {
        let d = threshold_accept(&[5, 6], &[5, 9, 8], &[0.9, 0.6, 0.1], 0.5);
        assert_eq!(d.accepted, 2);
        assert_eq!(d.committed, vec![5, 6, 8]);
    }

    #[test]
    fn threshold_rejects_improbable_mismatch() {
        let d = threshold_accept(&[5, 6], &[5, 9, 8], &[0.9, 0.2, 0.1], 0.5);
        assert_eq!(d.accepted, 1);
        assert_eq!(d.committed, vec![5, 9]);
    }
}
