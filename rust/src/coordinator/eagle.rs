//! EAGLE-style speculative-decoding baseline (Li et al. 2024b) for
//! Tables 5/7: a *separate* small draft model chain-drafts gamma=5
//! tokens which the W4A16 target verifies in parallel.
//!
//! Differences from QSPEC that this baseline makes measurable:
//!  * extra draft-model weights and a second KV cache (no sharing);
//!  * draft/target distributions diverge (two models) -> lower acceptance;
//!  * tree drafting (tree_k > 1) widens verification to ~k^(gamma-1)
//!    paths, blowing up verification cost and memory in batched serving —
//!    the simulated device-memory check reproduces the paper's OOM at
//!    batch 16. Tree verification cost/memory are modeled through the
//!    cost model (the executed path is the principal chain); DESIGN.md §3
//!    documents this substitution.
//!
//! Request plumbing lives in the shared [`BatchCore`]; this file is the
//! two-model draft/verify phase logic only. Through the [`Engine`]
//! trait this baseline is servable over TCP like any other engine.

use std::rc::Rc;

use crate::costmodel::{twins::Twin, CostModel, Phase};
use crate::error::Result;
use crate::kvcache::SlotManager;
use crate::metrics::{PhaseKind, PhaseTimer};
use crate::model::tokenizer::PAD;
use crate::model::Mode;
use crate::runtime::{ModelMeta, Module, Session, WeightSet};

use super::acceptance::{greedy_accept, stochastic_accept};
use super::engine::{BatchCore, Engine, StepBatch};
use super::request::StepEvent;

/// EAGLE baseline configuration.
#[derive(Clone, Debug)]
pub struct EagleConfig {
    /// target model size (paper: llama2-7b twin = "m").
    pub size: String,
    pub scheme: String,
    pub batch: usize,
    /// chain draft length (EAGLE default depth 5).
    pub gamma: usize,
    /// tree branching factor; 1 = chain. Tree cost/memory are modeled.
    pub tree_k: usize,
    /// mean context length used for the device-memory admission check.
    pub mem_ctx: usize,
}

impl EagleConfig {
    pub fn new(batch: usize, tree_k: usize) -> Self {
        EagleConfig {
            size: "m".to_string(),
            scheme: "atom".to_string(),
            batch,
            gamma: 5,
            tree_k,
            mem_ctx: 2048,
        }
    }

    /// Verification tokens per sequence the (modeled) tree would feed:
    /// EAGLE's tree materializes ~k^(gamma-1) paths but dedups shared
    /// prefixes; the official configuration verifies ~26 tree tokens.
    pub fn tree_tokens(&self) -> usize {
        if self.tree_k <= 1 {
            self.gamma + 1
        } else {
            (self.tree_k.pow(self.gamma as u32 - 1) + self.gamma).min(32)
        }
    }
}

/// The EAGLE baseline engine.
pub struct EagleEngine<'s> {
    #[allow(dead_code)]
    sess: &'s Session,
    pub cfg: EagleConfig,
    pub meta: ModelMeta,
    draft_meta: ModelMeta,
    // target model modules (W4A16)
    t_prefill: Rc<Module>,
    t_verify: Rc<Module>,
    t_weights: Rc<WeightSet>,
    // draft model modules (fp; paper uses an FP16 EAGLE head)
    d_prefill: Rc<Module>,
    d_draft: Rc<Module>,
    d_weights: Rc<WeightSet>,
    // logits twins (newer artifact sets only): present => the engine can
    // serve temperature > 0; absent => argmax-only
    t_prefill_logits: Option<Rc<Module>>,
    t_verify_logits: Option<Rc<Module>>,
    d_decode_logits: Option<Rc<Module>>,
    kv_target: Option<xla::PjRtBuffer>,
    kv_draft: Option<xla::PjRtBuffer>,
    pub core: BatchCore,
}

impl<'s> EagleEngine<'s> {
    /// Builds the engine; returns `Err(QspecError::Oom)` when the modeled
    /// device memory exceeds the L20 budget (Table 5/7 "OOM" rows).
    pub fn new(sess: &'s Session, cfg: EagleConfig) -> Result<Self> {
        let meta = sess.store.model(&cfg.size)?.clone();
        let draft_meta = sess.store.model("eagle")?.clone();
        let man = &sess.store.manifest;
        let t_prefill = sess.module(&cfg.size, &cfg.scheme, "w4a16", "prefill", cfg.batch, 0)?;
        let t_verify = sess.module(&cfg.size, &cfg.scheme, "w4a16", "verify", cfg.batch, cfg.gamma)?;
        let t_weights = sess.weights(&t_prefill.meta.weights_key)?;
        let d_prefill = sess.module("eagle", "atom", "w16a16", "prefill", cfg.batch, 0)?;
        let d_draft = sess.module("eagle", "atom", "w16a16", "draft", cfg.batch, cfg.gamma)?;
        let d_weights = sess.weights(&d_prefill.meta.weights_key)?;
        let t_prefill_logits = sess
            .module(&cfg.size, &cfg.scheme, "w4a16", "prefill_logits", cfg.batch, 0)
            .ok();
        let t_verify_logits = sess
            .module(&cfg.size, &cfg.scheme, "w4a16", "verify_logits", cfg.batch, cfg.gamma)
            .ok();
        let d_decode_logits =
            sess.module("eagle", "atom", "w16a16", "decode_logits", cfg.batch, 0).ok();

        let cost = CostModel::new(Twin::lookup(&meta.paper_twin));
        let draft_twin = Twin::lookup("eagle-head");
        // ---- simulated device-memory admission (the OOM reproduction) --
        let target_resident = cost.weight_bytes(Mode::W4A16)
            + cost.kv_bytes(Mode::W4A16, cfg.batch, cfg.mem_ctx);
        let draft_resident = 2 * draft_twin.n_params // fp16 draft weights
            + cfg.batch * cfg.mem_ctx * draft_twin.kv_bytes_per_token(Mode::W16A16);
        // tree verification workspace: per-branch K/V + attention
        // activations for k^(gamma-1) paths (calibrated; DESIGN.md §3)
        let tree_ws = if cfg.tree_k > 1 {
            cfg.batch
                * cfg.tree_k.pow(cfg.gamma as u32 - 1)
                * cfg.mem_ctx
                * Twin::lookup(&meta.paper_twin).kv_bytes_per_token(Mode::W4A16)
                / 8
        } else {
            0
        };
        cost.check_memory(
            target_resident + draft_resident + tree_ws,
            &format!("eagle b={} k={}", cfg.batch, cfg.tree_k),
        )?;

        let kv_target = Some(sess.fresh_kv(&cfg.size, cfg.batch)?);
        let kv_draft = Some(sess.fresh_kv("eagle", cfg.batch)?);
        let max_seq = meta.max_seq.min(draft_meta.max_seq);
        let slots = SlotManager::new(cfg.batch, max_seq, man.prefill_t);

        Ok(EagleEngine {
            sess,
            cfg,
            meta,
            draft_meta,
            t_prefill,
            t_verify,
            t_weights,
            d_prefill,
            d_draft,
            d_weights,
            t_prefill_logits,
            t_verify_logits,
            d_decode_logits,
            kv_target,
            kv_draft,
            core: BatchCore::new(slots, cost),
        })
    }

    fn admit_and_prefill(&mut self, out: &mut Vec<StepEvent>) -> Result<()> {
        let pb = match self.core.admit_batch(out)? {
            Some(pb) => pb,
            None => return Ok(()),
        };
        let p = self.core.slots.prefill_t();
        // target prefill
        let timer = PhaseTimer::start();
        let kv = self.kv_target.take().expect("kv");
        let stochastic = pb.admitted.iter().any(|(i, _)| self.core.slot_stochastic(*i));
        let ftok = if stochastic && self.t_prefill_logits.is_some() {
            // logits twin: identical KV writes, first token sampled (or
            // argmax'd for greedy slots) host-side
            let pm = self.t_prefill_logits.clone().expect("prefill_logits");
            let r = pm.call_prefill_logits(&pb.tokens, &pb.start, &pb.mask, &kv, &self.t_weights)?;
            self.kv_target = Some(r.kv);
            let vocab = self.meta.vocab;
            let mut tok = vec![PAD; self.cfg.batch];
            for (i, _) in &pb.admitted {
                let row = &r.logits[i * vocab..(i + 1) * vocab];
                tok[*i] = match self.core.sampler_mut(*i) {
                    Some(s) => {
                        let pr = s.probs(row);
                        s.sample_probs(&pr) as i32
                    }
                    None => crate::sampler::argmax(row) as i32,
                };
            }
            tok
        } else {
            let r = self
                .t_prefill
                .call_prefill(&pb.tokens, &pb.start, &pb.mask, &kv, &self.t_weights)?;
            self.kv_target = Some(r.kv);
            r.tok
        };
        // prefill is priced per *uncached* token: blocks attached from
        // the prefix cache carry committed KV and cost no compute
        let virt = self
            .core
            .cost
            .charge(Mode::W4A16, Phase::Chunk, pb.admitted.len(), pb.uncached_tokens(), p);
        self.core.metrics.add_phase(PhaseKind::Prefill, timer.elapsed_ns(), virt);
        // draft-model prefill (its own cache — the memory overhead QSPEC avoids)
        let timer = PhaseTimer::start();
        let dkv = self.kv_draft.take().expect("dkv");
        let r2 = self
            .d_prefill
            .call_prefill(&pb.tokens, &pb.start, &pb.mask, &dkv, &self.d_weights)?;
        self.kv_draft = Some(r2.kv);
        self.core.metrics.add_phase(PhaseKind::Prefill, timer.elapsed_ns(), 0);
        self.core.finish_prefill(&pb, &ftok, out);
        Ok(())
    }

    fn cycle(&mut self, out: &mut Vec<StepEvent>) -> Result<()> {
        let sb = match self.core.step_inputs() {
            Some(sb) => sb,
            None => return Ok(()),
        };
        if self.core.any_stochastic(&sb.active)
            && self.d_decode_logits.is_some()
            && self.t_verify_logits.is_some()
        {
            return self.cycle_stochastic(&sb, out);
        }
        let b = self.cfg.batch;
        let g = self.cfg.gamma;

        // draft: the separate FP16 draft model, chain of gamma steps
        let timer = PhaseTimer::start();
        let dkv = self.kv_draft.take().expect("dkv");
        let d = self.d_draft.call_draft(&sb.tok, &sb.pos, &sb.start, &dkv, &self.d_weights)?;
        self.kv_draft = Some(d.kv);
        let draft_twin = Twin::lookup("eagle-head");
        let mut virt = 0u128;
        for _ in 0..g {
            // draft decode steps on the small fp model, same device clock
            virt += CostModel::ns_for(
                &draft_twin,
                Mode::W16A16,
                Phase::Decode,
                sb.active.len(),
                1,
                sb.mean_ctx,
            );
        }
        self.core.cost.virtual_ns += virt;
        self.core.metrics.add_phase(PhaseKind::Draft, timer.elapsed_ns(), virt);

        // verify on the target (tree cost modeled via tree_tokens)
        let mut vtokens = vec![PAD; b * (g + 1)];
        for slot in 0..b {
            vtokens[slot * (g + 1)] = sb.tok[slot];
            for j in 0..g {
                vtokens[slot * (g + 1) + 1 + j] = d.toks[slot * g + j];
            }
        }
        let timer = PhaseTimer::start();
        let kv = self.kv_target.take().expect("kv");
        let v = self
            .t_verify
            .call_verify(&vtokens, &sb.pos, &sb.start, &sb.mask, &kv, &self.t_weights)?;
        self.kv_target = Some(v.kv);
        let virt = self.core.cost.charge(
            Mode::W4A16,
            Phase::Chunk,
            sb.active.len(),
            self.cfg.tree_tokens(),
            sb.mean_ctx,
        );
        self.core.metrics.add_phase(PhaseKind::Verify, timer.elapsed_ns(), virt);

        let timer = PhaseTimer::start();
        for &i in &sb.active {
            let drafts = &d.toks[i * g..(i + 1) * g];
            let vt = &v.vtok[i * (g + 1)..(i + 1) * (g + 1)];
            let dec = greedy_accept(drafts, vt);
            self.core.metrics.drafted += g as u64;
            self.core.metrics.accepted += dec.accepted as u64;
            self.core.metrics.record_accept(dec.accepted as u64);
            self.core.commit(i, &dec.committed, g, out);
        }
        self.core.metrics.add_phase(PhaseKind::Host, timer.elapsed_ns(), 0);
        Ok(())
    }

    /// The stochastic cycle: the fp draft head chain-drafts via gamma
    /// sequential `decode_logits` steps (host sampling from the draft
    /// distribution q), the target verifies via `verify_logits`, then
    /// the Leviathan accept rule runs per slot — the two-model setting
    /// the rule was designed for (q and p genuinely diverge). Cost
    /// charges match the greedy cycle (incl. the modeled tree tokens).
    fn cycle_stochastic(&mut self, sb: &StepBatch, out: &mut Vec<StepEvent>) -> Result<()> {
        let b = self.cfg.batch;
        let g = self.cfg.gamma;
        let vocab = self.meta.vocab;
        let dm = self.d_decode_logits.clone().expect("decode_logits");
        let vm = self.t_verify_logits.clone().expect("verify_logits");

        // draft: sequential chain on the separate fp head, own cache
        let timer = PhaseTimer::start();
        let mut cur = sb.tok.clone();
        let mut drafts = vec![PAD; b * g];
        let mut q = vec![0f32; b * g * vocab];
        for j in 0..g {
            let pos: Vec<i32> = sb.pos.iter().map(|&p| p + j as i32).collect();
            let dkv = self.kv_draft.take().expect("dkv");
            let r = dm.call_decode_logits(&cur, &pos, &sb.start, &dkv, &self.d_weights)?;
            self.kv_draft = Some(r.kv);
            for &i in &sb.active {
                let row = &r.logits[i * vocab..(i + 1) * vocab];
                let d = match self.core.sampler_mut(i) {
                    Some(s) => {
                        let qp = s.probs(row);
                        let d = s.sample_probs(&qp);
                        let at = (i * g + j) * vocab;
                        q[at..at + vocab].copy_from_slice(&qp);
                        d
                    }
                    None => crate::sampler::argmax(row),
                } as i32;
                drafts[i * g + j] = d;
                cur[i] = d;
            }
        }
        let draft_twin = Twin::lookup("eagle-head");
        let mut virt = 0u128;
        for _ in 0..g {
            virt += CostModel::ns_for(
                &draft_twin,
                Mode::W16A16,
                Phase::Decode,
                sb.active.len(),
                1,
                sb.mean_ctx,
            );
        }
        self.core.cost.virtual_ns += virt;
        self.core.metrics.add_phase(PhaseKind::Draft, timer.elapsed_ns(), virt);

        // verify on the target (tree cost modeled via tree_tokens)
        let mut vtokens = vec![PAD; b * (g + 1)];
        for slot in 0..b {
            vtokens[slot * (g + 1)] = sb.tok[slot];
            for j in 0..g {
                vtokens[slot * (g + 1) + 1 + j] = drafts[slot * g + j];
            }
        }
        let timer = PhaseTimer::start();
        let kv = self.kv_target.take().expect("kv");
        let v = vm.call_verify_logits(&vtokens, &sb.pos, &sb.start, &sb.mask, &kv, &self.t_weights)?;
        self.kv_target = Some(v.kv);
        let virt = self.core.cost.charge(
            Mode::W4A16,
            Phase::Chunk,
            sb.active.len(),
            self.cfg.tree_tokens(),
            sb.mean_ctx,
        );
        self.core.metrics.add_phase(PhaseKind::Verify, timer.elapsed_ns(), virt);

        let timer = PhaseTimer::start();
        for &i in &sb.active {
            let dr = &drafts[i * g..(i + 1) * g];
            let vrows = &v.logits[i * (g + 1) * vocab..(i + 1) * (g + 1) * vocab];
            let dec = match self.core.sampler_mut(i) {
                Some(s) => {
                    let mut p = Vec::with_capacity((g + 1) * vocab);
                    for j in 0..=g {
                        p.extend(s.probs(&vrows[j * vocab..(j + 1) * vocab]));
                    }
                    stochastic_accept(dr, &q[i * g * vocab..(i + 1) * g * vocab], &p, vocab, s)
                }
                None => {
                    let vt: Vec<i32> = (0..=g)
                        .map(|j| crate::sampler::argmax(&vrows[j * vocab..(j + 1) * vocab]) as i32)
                        .collect();
                    greedy_accept(dr, &vt)
                }
            };
            self.core.metrics.drafted += g as u64;
            self.core.metrics.accepted += dec.accepted as u64;
            self.core.metrics.record_accept(dec.accepted as u64);
            self.core.commit(i, &dec.committed, g, out);
        }
        self.core.metrics.add_phase(PhaseKind::Host, timer.elapsed_ns(), 0);
        Ok(())
    }

    pub fn draft_model_meta(&self) -> &ModelMeta {
        &self.draft_meta
    }
}

impl<'s> Engine for EagleEngine<'s> {
    fn name(&self) -> &'static str {
        "eagle"
    }

    fn argmax_only(&self) -> bool {
        self.t_prefill_logits.is_none()
            || self.t_verify_logits.is_none()
            || self.d_decode_logits.is_none()
    }

    fn core(&self) -> &BatchCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BatchCore {
        &mut self.core
    }

    fn step(&mut self) -> Result<Vec<StepEvent>> {
        let mut out = Vec::new();
        self.admit_and_prefill(&mut out)?;
        self.cycle(&mut out)?;
        Ok(out)
    }
}
