//! Request / response types of the serving API.

use std::time::Instant;

/// One generation request (token-level; the server layer tokenizes).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_tokens: usize,
    pub arrival: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_tokens: usize) -> Self {
        Request { id, prompt, max_tokens, arrival: Instant::now() }
    }
}

/// A finished request with its generated tokens and latency.
#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// end-to-end latency (submit -> finish).
    pub latency_ns: u128,
    /// time spent waiting in the FCFS queue (submit -> admission).
    pub queue_ns: u128,
}
