//! Request / response types of the serving API (protocol v1).
//!
//! The serving surface is request/event shaped: callers build a
//! [`GenerationRequest`] (prompt + per-request [`SamplingParams`]),
//! engines emit [`StepEvent`]s — a [`StepEvent::Delta`] for every batch
//! of committed tokens and a terminal [`StepEvent::Done`] carrying the
//! [`Finished`] usage record with its [`FinishReason`]. The server maps
//! these 1:1 onto wire frames; offline drivers (benches, evalsuite,
//! CLI) collect the `Done` events through `Engine::run_to_completion`.

use std::time::Instant;

use crate::error::{QspecError, Result};

/// Ceilings on per-request stop sequences (a client knob — bounded so a
/// request cannot make every commit scan arbitrarily long suffixes).
pub const MAX_STOP_SEQUENCES: usize = 4;
pub const MAX_STOP_TOKENS: usize = 32;

/// Per-request sampling / termination parameters.
///
/// `temperature` and `seed` are threaded through every layer and
/// validated, but the AOT-compiled entries return greedy argmax tokens
/// (the paper's reproducibility setup) and logits never cross the host
/// boundary, so generation currently behaves as temperature 0 for any
/// accepted value; the fields exist so host-side samplers and future
/// sampling entries consume them without another API change.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// generation budget (counting the prefill's first token).
    pub max_tokens: usize,
    /// token-level stop sequences: generation ends (finish_reason
    /// `Stop`) when the generated tail matches any of them; the matched
    /// tokens are trimmed from the output.
    pub stop: Vec<Vec<i32>>,
    /// 0.0 = greedy (default, the paper setting). Validated to [0, 2].
    pub temperature: f32,
    /// PRNG seed for temperature sampling (unused when greedy).
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { max_tokens: 64, stop: Vec::new(), temperature: 0.0, seed: 0 }
    }
}

impl SamplingParams {
    /// Greedy decode with a generation budget — the historical
    /// `(prompt, max_tokens)` API expressed as params.
    pub fn greedy(max_tokens: usize) -> Self {
        SamplingParams { max_tokens, ..SamplingParams::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_tokens == 0 {
            return Err(QspecError::Config("max_tokens must be >= 1".into()));
        }
        if !self.temperature.is_finite() || !(0.0..=2.0).contains(&self.temperature) {
            return Err(QspecError::Config(format!(
                "temperature {} outside [0, 2]",
                self.temperature
            )));
        }
        if self.stop.len() > MAX_STOP_SEQUENCES {
            return Err(QspecError::Config(format!(
                "at most {MAX_STOP_SEQUENCES} stop sequences (got {})",
                self.stop.len()
            )));
        }
        for s in &self.stop {
            if s.is_empty() || s.len() > MAX_STOP_TOKENS {
                return Err(QspecError::Config(format!(
                    "stop sequences must be 1..={MAX_STOP_TOKENS} tokens (got {})",
                    s.len()
                )));
            }
        }
        Ok(())
    }
}

/// One generation request as submitted by a client (token-level; the
/// server layer tokenizes prompt and stop strings).
#[derive(Clone, Debug)]
pub struct GenerationRequest {
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
}

impl GenerationRequest {
    pub fn new(prompt: Vec<i32>, params: SamplingParams) -> Self {
        GenerationRequest { prompt, params }
    }

    /// The legacy `(prompt, max_tokens)` form: greedy, no stops.
    pub fn greedy(prompt: Vec<i32>, max_tokens: usize) -> Self {
        GenerationRequest { prompt, params: SamplingParams::greedy(max_tokens) }
    }
}

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// budget exhausted (max_tokens) or out of KV-cache headroom.
    Length,
    /// natural stop: EOS emitted or a stop sequence matched.
    Stop,
    /// cancelled by the client (explicit op or disconnect).
    Cancelled,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
        }
    }
}

/// Internal queued request: id assigned by the engine's `BatchCore`,
/// arrival stamped at submission.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    pub arrival: Instant,
}

impl Request {
    /// Greedy request (tests and legacy call sites).
    pub fn new(id: u64, prompt: Vec<i32>, max_tokens: usize) -> Self {
        Self::with_params(id, prompt, SamplingParams::greedy(max_tokens))
    }

    pub fn with_params(id: u64, prompt: Vec<i32>, params: SamplingParams) -> Self {
        Request { id, prompt, params, arrival: Instant::now() }
    }

    pub fn max_tokens(&self) -> usize {
        self.params.max_tokens
    }
}

/// A finished request: the generated tokens plus its usage record.
#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub finish_reason: FinishReason,
    /// prompt length in tokens (usage accounting).
    pub prompt_tokens: usize,
    /// end-to-end latency (submit -> finish).
    pub latency_ns: u128,
    /// time spent waiting in the FCFS queue (submit -> admission).
    pub queue_ns: u128,
}

/// Incremental output of one `Engine::step`.
#[derive(Clone, Debug)]
pub enum StepEvent {
    /// Tokens committed for request `id` this step (streamed to the
    /// client as they land).
    Delta { id: u64, tokens: Vec<i32> },
    /// Terminal event: the request finished (or was cancelled) and its
    /// slot is already released.
    Done(Finished),
}

impl StepEvent {
    pub fn into_done(self) -> Option<Finished> {
        match self {
            StepEvent::Done(f) => Some(f),
            StepEvent::Delta { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_params_are_valid_and_greedy() {
        let p = SamplingParams::default();
        assert!(p.validate().is_ok());
        assert_eq!(p.temperature, 0.0);
        assert!(p.stop.is_empty());
    }

    #[test]
    fn validate_rejects_bad_params() {
        let mut p = SamplingParams::greedy(0);
        assert!(p.validate().is_err());
        p = SamplingParams::greedy(8);
        p.temperature = 3.0;
        assert!(p.validate().is_err());
        p.temperature = f32::NAN;
        assert!(p.validate().is_err());
        p.temperature = 0.7;
        assert!(p.validate().is_ok());
        p.stop = vec![Vec::new()];
        assert!(p.validate().is_err());
        p.stop = vec![vec![1; MAX_STOP_TOKENS + 1]];
        assert!(p.validate().is_err());
        p.stop = vec![vec![5, 6]; MAX_STOP_SEQUENCES + 1];
        assert!(p.validate().is_err());
    }

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
    }

    #[test]
    fn legacy_request_constructor_maps_to_greedy_params() {
        let r = Request::new(3, vec![1, 2], 17);
        assert_eq!(r.max_tokens(), 17);
        assert_eq!(r.params.temperature, 0.0);
        let g = GenerationRequest::greedy(vec![1], 9);
        assert_eq!(g.params.max_tokens, 9);
    }

    #[test]
    fn step_event_into_done() {
        assert!(StepEvent::Delta { id: 0, tokens: vec![1] }.into_done().is_none());
        let f = Finished {
            id: 1,
            tokens: vec![],
            finish_reason: FinishReason::Stop,
            prompt_tokens: 2,
            latency_ns: 0,
            queue_ns: 0,
        };
        assert_eq!(StepEvent::Done(f).into_done().unwrap().id, 1);
    }
}
