//! Request / response types of the serving API (protocol v1.1).
//!
//! The serving surface is request/event shaped: callers build a
//! [`GenerationRequest`] (prompt + per-request [`SamplingParams`] +
//! QoS intent: a validated [`priority`](GenerationRequest::priority)
//! class and an optional relative deadline), engines emit
//! [`StepEvent`]s — a [`StepEvent::Delta`] for every batch of committed
//! tokens and a terminal [`StepEvent::Done`] carrying the [`Finished`]
//! usage record with its [`FinishReason`]. The server maps these 1:1
//! onto wire frames; offline drivers (benches, evalsuite, CLI) collect
//! the `Done` events through `Engine::run_to_completion`.
//!
//! QoS semantics: `priority` selects one of [`NUM_PRIORITY_CLASSES`]
//! classes (higher = more urgent; [`DEFAULT_PRIORITY`] for requests
//! that don't say). `deadline_ms` is a latency budget relative to
//! submission; a request whose budget has already lapsed when a slot
//! would admit it terminates with
//! [`FinishReason::DeadlineExceeded`] instead of burning the slot.
//! Both fields only change *ordering/shedding* under a non-FCFS
//! [`SchedPolicy`](super::queue::SchedPolicy) or an admission SLO —
//! legacy v1 traffic (all defaults) behaves exactly as before.

use std::time::{Duration, Instant};

use crate::error::{QspecError, Result};

/// Ceilings on per-request stop sequences (a client knob — bounded so a
/// request cannot make every commit scan arbitrarily long suffixes).
pub const MAX_STOP_SEQUENCES: usize = 4;
pub const MAX_STOP_TOKENS: usize = 32;

/// Priority classes of the QoS surface: 0 = batch/background,
/// 1 = normal (the default), 2 = high, 3 = critical. Higher wins under
/// the priority scheduler; classes >= the configured shed threshold are
/// exempt from admission shedding.
pub const NUM_PRIORITY_CLASSES: usize = 4;
pub const MAX_PRIORITY: u8 = (NUM_PRIORITY_CLASSES - 1) as u8;
pub const DEFAULT_PRIORITY: u8 = 1;

/// Per-request sampling / termination parameters.
///
/// `temperature > 0` is served end-to-end: engines whose artifact set
/// exports the `*_logits` entry twins sample host-side (per-request
/// [`Sampler`](crate::sampler::Sampler), seeded by `seed`) and run
/// stochastic speculative acceptance
/// ([`stochastic_accept`](crate::coordinator::stochastic_accept)), so
/// the committed stream is distributed exactly as a verifier-only
/// rollout and identical requests replay identically. Engines built
/// from a pre-logits artifact set advertise `Engine::argmax_only`; the
/// server rejects `temperature > 0` against those with a precise
/// `bad_request` (and the CLI warns) instead of silently decoding
/// greedily.
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingParams {
    /// generation budget (counting the prefill's first token).
    pub max_tokens: usize,
    /// token-level stop sequences: generation ends (finish_reason
    /// `Stop`) when the generated tail matches any of them; the matched
    /// tokens are trimmed from the output.
    pub stop: Vec<Vec<i32>>,
    /// 0.0 = greedy (default, the paper setting). Validated to [0, 2].
    pub temperature: f32,
    /// PRNG seed for temperature sampling (unused when greedy).
    pub seed: u64,
    /// v1.7: keep only the `top_k` most probable tokens (0 = off).
    /// Truncation is applied to *both* the draft and verifier
    /// distributions before the stochastic accept test, then each is
    /// renormalized — so speculation stays lossless with respect to
    /// the truncated verifier distribution. Ignored when greedy.
    pub top_k: usize,
    /// v1.7: nucleus truncation — keep the smallest prefix of the
    /// probability-sorted vocabulary whose cumulative mass reaches
    /// `top_p` (1.0 = off). Validated to (0, 1]; composes with
    /// `top_k` (top-k first, then the nucleus cut). Ignored when
    /// greedy.
    pub top_p: f32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            max_tokens: 64,
            stop: Vec::new(),
            temperature: 0.0,
            seed: 0,
            top_k: 0,
            top_p: 1.0,
        }
    }
}

impl SamplingParams {
    /// Greedy decode with a generation budget — the historical
    /// `(prompt, max_tokens)` API expressed as params.
    pub fn greedy(max_tokens: usize) -> Self {
        SamplingParams { max_tokens, ..SamplingParams::default() }
    }

    pub fn validate(&self) -> Result<()> {
        if self.max_tokens == 0 {
            return Err(QspecError::Config("max_tokens must be >= 1".into()));
        }
        if !self.temperature.is_finite() || !(0.0..=2.0).contains(&self.temperature) {
            return Err(QspecError::Config(format!(
                "temperature {} outside [0, 2]",
                self.temperature
            )));
        }
        if !self.top_p.is_finite() || !(self.top_p > 0.0 && self.top_p <= 1.0) {
            return Err(QspecError::Config(format!(
                "top_p {} outside (0, 1]",
                self.top_p
            )));
        }
        if self.stop.len() > MAX_STOP_SEQUENCES {
            return Err(QspecError::Config(format!(
                "at most {MAX_STOP_SEQUENCES} stop sequences (got {})",
                self.stop.len()
            )));
        }
        for s in &self.stop {
            if s.is_empty() || s.len() > MAX_STOP_TOKENS {
                return Err(QspecError::Config(format!(
                    "stop sequences must be 1..={MAX_STOP_TOKENS} tokens (got {})",
                    s.len()
                )));
            }
        }
        Ok(())
    }
}

/// One generation request as submitted by a client (token-level; the
/// server layer tokenizes prompt and stop strings).
#[derive(Clone, Debug)]
pub struct GenerationRequest {
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    /// QoS class in `0..NUM_PRIORITY_CLASSES` (higher = more urgent);
    /// [`DEFAULT_PRIORITY`] when the wire frame omits it, which makes
    /// every scheduler behave FCFS-equivalently for legacy traffic.
    pub priority: u8,
    /// Latency budget relative to submission: the request must reach a
    /// slot (and finish) within this many ms or it terminates with
    /// [`FinishReason::DeadlineExceeded`] at admission. `None` = no
    /// deadline.
    pub deadline_ms: Option<u64>,
}

impl GenerationRequest {
    pub fn new(prompt: Vec<i32>, params: SamplingParams) -> Self {
        GenerationRequest { prompt, params, priority: DEFAULT_PRIORITY, deadline_ms: None }
    }

    /// The legacy `(prompt, max_tokens)` form: greedy, no stops.
    pub fn greedy(prompt: Vec<i32>, max_tokens: usize) -> Self {
        Self::new(prompt, SamplingParams::greedy(max_tokens))
    }

    /// Builder-style QoS setters (the server parse layer and the CLI
    /// thread wire fields through these).
    pub fn with_priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> Self {
        self.deadline_ms = Some(deadline_ms);
        self
    }

    /// Full-request validation: sampling params plus the QoS fields.
    pub fn validate(&self) -> Result<()> {
        self.params.validate()?;
        if self.priority > MAX_PRIORITY {
            return Err(QspecError::Config(format!(
                "priority {} outside 0..={MAX_PRIORITY}",
                self.priority
            )));
        }
        if self.deadline_ms == Some(0) {
            return Err(QspecError::Config("deadline_ms must be >= 1".into()));
        }
        Ok(())
    }
}

/// Structured admission rejection: the server answers with an
/// `overloaded` error frame carrying `retry_after_ms` so well-behaved
/// clients back off instead of hammering a saturated queue.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Overload {
    pub retry_after_ms: u64,
    /// which SLO signal tripped, with its observed value.
    pub message: String,
    /// which priority class's threshold tripped (v1.2: per-class shed
    /// tables make this ambiguous without it); `None` for sheds that
    /// are not class-driven (e.g. every pool replica draining).
    pub class: Option<u8>,
}

/// Why a request stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// budget exhausted (max_tokens) or out of KV-cache headroom.
    Length,
    /// natural stop: EOS emitted or a stop sequence matched.
    Stop,
    /// cancelled by the client (explicit op or disconnect).
    Cancelled,
    /// the request's latency budget lapsed while it was still queued;
    /// expired at admission time without occupying a slot.
    DeadlineExceeded,
}

impl FinishReason {
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Cancelled => "cancelled",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
        }
    }
}

/// Internal queued request: id assigned by the engine's `BatchCore`,
/// arrival stamped at submission, deadline resolved to an absolute
/// instant (EDF orders on it; admission expires on it).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub params: SamplingParams,
    pub arrival: Instant,
    pub priority: u8,
    /// absolute deadline (`arrival + deadline_ms`); `None` = unbounded.
    pub deadline: Option<Instant>,
}

impl Request {
    /// Greedy request (tests and legacy call sites).
    pub fn new(id: u64, prompt: Vec<i32>, max_tokens: usize) -> Self {
        Self::with_params(id, prompt, SamplingParams::greedy(max_tokens))
    }

    pub fn with_params(id: u64, prompt: Vec<i32>, params: SamplingParams) -> Self {
        Self::with_qos(id, prompt, params, DEFAULT_PRIORITY, None)
    }

    /// Full constructor: QoS fields resolved at submission time.
    pub fn with_qos(
        id: u64,
        prompt: Vec<i32>,
        params: SamplingParams,
        priority: u8,
        deadline_ms: Option<u64>,
    ) -> Self {
        let arrival = Instant::now();
        let deadline = deadline_ms.map(|ms| arrival + Duration::from_millis(ms));
        Request { id, prompt, params, arrival, priority, deadline }
    }

    /// Build the queued form of a submitted [`GenerationRequest`].
    pub fn from_generation(id: u64, g: GenerationRequest) -> Self {
        Self::with_qos(id, g.prompt, g.params, g.priority, g.deadline_ms)
    }

    pub fn max_tokens(&self) -> usize {
        self.params.max_tokens
    }

    /// Whether the request's latency budget has already lapsed.
    pub fn expired(&self) -> bool {
        self.deadline.is_some_and(|d| Instant::now() >= d)
    }
}

/// A finished request: the generated tokens plus its usage record.
#[derive(Clone, Debug)]
pub struct Finished {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub finish_reason: FinishReason,
    /// prompt length in tokens (usage accounting).
    pub prompt_tokens: usize,
    /// end-to-end latency (submit -> finish).
    pub latency_ns: u128,
    /// time spent waiting in the FCFS queue (submit -> admission).
    pub queue_ns: u128,
}

/// Incremental output of one `Engine::step`.
#[derive(Clone, Debug)]
pub enum StepEvent {
    /// Tokens committed for request `id` this step (streamed to the
    /// client as they land).
    Delta { id: u64, tokens: Vec<i32> },
    /// Terminal event: the request finished (or was cancelled) and its
    /// slot is already released.
    Done(Finished),
}

impl StepEvent {
    pub fn into_done(self) -> Option<Finished> {
        match self {
            StepEvent::Done(f) => Some(f),
            StepEvent::Delta { .. } => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_params_are_valid_and_greedy() {
        let p = SamplingParams::default();
        assert!(p.validate().is_ok());
        assert_eq!(p.temperature, 0.0);
        assert!(p.stop.is_empty());
    }

    #[test]
    fn validate_rejects_bad_params() {
        let mut p = SamplingParams::greedy(0);
        assert!(p.validate().is_err());
        p = SamplingParams::greedy(8);
        p.temperature = 3.0;
        assert!(p.validate().is_err());
        p.temperature = f32::NAN;
        assert!(p.validate().is_err());
        p.temperature = 0.7;
        assert!(p.validate().is_ok());
        p.top_p = 0.0;
        assert!(p.validate().is_err());
        p.top_p = 1.5;
        assert!(p.validate().is_err());
        p.top_p = f32::NAN;
        assert!(p.validate().is_err());
        p.top_p = 0.9;
        p.top_k = 5;
        assert!(p.validate().is_ok());
        p.stop = vec![Vec::new()];
        assert!(p.validate().is_err());
        p.stop = vec![vec![1; MAX_STOP_TOKENS + 1]];
        assert!(p.validate().is_err());
        p.stop = vec![vec![5, 6]; MAX_STOP_SEQUENCES + 1];
        assert!(p.validate().is_err());
    }

    #[test]
    fn finish_reason_labels() {
        assert_eq!(FinishReason::Length.as_str(), "length");
        assert_eq!(FinishReason::Stop.as_str(), "stop");
        assert_eq!(FinishReason::Cancelled.as_str(), "cancelled");
        assert_eq!(FinishReason::DeadlineExceeded.as_str(), "deadline_exceeded");
    }

    #[test]
    fn legacy_request_constructor_maps_to_greedy_params() {
        let r = Request::new(3, vec![1, 2], 17);
        assert_eq!(r.max_tokens(), 17);
        assert_eq!(r.params.temperature, 0.0);
        // legacy requests carry FCFS-equivalent QoS defaults
        assert_eq!(r.priority, DEFAULT_PRIORITY);
        assert!(r.deadline.is_none());
        assert!(!r.expired());
        let g = GenerationRequest::greedy(vec![1], 9);
        assert_eq!(g.params.max_tokens, 9);
        assert_eq!(g.priority, DEFAULT_PRIORITY);
        assert!(g.deadline_ms.is_none());
    }

    #[test]
    fn qos_validation() {
        let g = GenerationRequest::greedy(vec![1], 4);
        assert!(g.validate().is_ok());
        let g = GenerationRequest::greedy(vec![1], 4).with_priority(MAX_PRIORITY);
        assert!(g.validate().is_ok());
        let g = GenerationRequest::greedy(vec![1], 4).with_priority(MAX_PRIORITY + 1);
        assert!(g.validate().is_err());
        let g = GenerationRequest::greedy(vec![1], 4).with_deadline_ms(0);
        assert!(g.validate().is_err());
        let g = GenerationRequest::greedy(vec![1], 4).with_deadline_ms(250);
        assert!(g.validate().is_ok());
        // bad sampling params fail through the same entry point
        let g = GenerationRequest::new(vec![1], SamplingParams::greedy(0));
        assert!(g.validate().is_err());
    }

    #[test]
    fn deadline_resolves_to_absolute_instant_and_expires() {
        let g = GenerationRequest::greedy(vec![1], 4).with_deadline_ms(60_000);
        let r = Request::from_generation(5, g);
        assert_eq!(r.id, 5);
        assert!(r.deadline.is_some());
        assert!(!r.expired(), "a 60s budget cannot have lapsed yet");
        let r = Request::with_qos(6, vec![1], SamplingParams::greedy(4), 2, Some(1));
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(r.expired(), "a 1ms budget lapses");
        assert_eq!(r.priority, 2);
    }

    #[test]
    fn step_event_into_done() {
        assert!(StepEvent::Delta { id: 0, tokens: vec![1] }.into_done().is_none());
        let f = Finished {
            id: 1,
            tokens: vec![],
            finish_reason: FinishReason::Stop,
            prompt_tokens: 2,
            latency_ns: 0,
            queue_ns: 0,
        };
        assert_eq!(StepEvent::Done(f).into_done().unwrap().id, 1);
    }
}
