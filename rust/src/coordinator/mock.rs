//! Session-free mock engine: deterministic echo decoding over the real
//! [`BatchCore`], no artifacts or PJRT session required.
//!
//! Prefill emits token 10; each scheduling cycle commits `pending + 1,
//! pending + 2, ...` so the output text is deterministic ("hijk..."
//! under the test alphabet) and streaming/stop/cancel semantics are
//! fully exercised. Two knobs shape it into a pool replica stand-in:
//!
//! * `step_delay` — per-cycle sleep, widening cancellation race
//!   windows and letting benches model slow replicas;
//! * `with_acceptance(a)` — simulate a drafting engine: every cycle
//!   drafts `gamma` tokens, accepts `round(gamma * a)` of them, and
//!   commits `1 + accepted` tokens. Acceptance shows up in
//!   `metrics.drafted/accepted` (so `acceptance_rate ~= a`) *and* in
//!   throughput (more tokens per fixed-delay cycle), which is exactly
//!   the signal the pool's `acceptance_aware` route policy bets on.
//!
//! For the v1.4 lifecycle layer (transport failover, respawn,
//! autoscaling) the mock grows two more knobs: [`FailureMode`] fault
//! injection (panic, stall, or clean error after N working cycles, so
//! replica-death paths are reachable without killing processes) and a
//! settable draft depth via [`Engine::reconfigure`], making the
//! router's live `reconfigure` op observable session-free.
//!
//! The protocol test suites and `benches/pool_router.rs` build mock
//! replica pools from this engine; `tests/engine_trait.rs` runs it
//! through the same conformance battery as the real engines.
//!
//! **Stochastic sampling** (`temperature > 0`) is served too, exactly
//! the way the real engines do it: slots with a per-request
//! [`Sampler`](crate::sampler::Sampler) decode against a deterministic
//! toy conditional LM ([`mock_logits`]) — the "verifier" distribution
//! `p` — and, in acceptance-simulation mode, draft from a deliberately
//! perturbed distribution `q` ([`mock_draft_logits`], noise amplitude
//! shrinking as the acceptance knob rises) run through
//! [`stochastic_accept`]. The accept rule makes the committed stream
//! distributed exactly as a pure rollout of `p` whatever `q` is, which
//! the session-free TV-distance suite checks end-to-end. Greedy slots
//! keep the deterministic echo, so every pre-existing test is
//! unchanged.

use std::time::Duration;

use crate::costmodel::{twins::Twin, CostModel, Phase};
use crate::error::{QspecError, Result};
use crate::kvcache::SlotManager;
use crate::model::{Mode, Tokenizer};

use crate::sampler::{argmax, softmax};
use crate::tree::TokenTree;

use super::acceptance::{greedy_tree_accept, stochastic_accept, stochastic_tree_accept};
use super::engine::{BatchCore, Engine};
use super::request::StepEvent;
use super::treespec::top_candidates;

/// Default draft depth of the simulated speculative mode (retunable
/// per engine instance through [`Engine::reconfigure`]).
pub const MOCK_GAMMA: usize = 4;

/// Injected fault for lifecycle tests and failover benches: all three
/// modes count *working* scheduling cycles (idle waits don't step the
/// engine), so `PanicAfterN(3)` fires on the 4th cycle that actually
/// processes work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureMode {
    /// `panic!` in `step()` once more than N cycles have run — models a
    /// replica thread/process dying hard (the channel closes, a remote
    /// worker's socket drops without a goodbye).
    PanicAfterN(u64),
    /// One-time `sleep(ms)` on exactly cycle N — models a wedged or
    /// GC-pausing replica that is still alive (heartbeats keep flowing;
    /// the router must *not* declare it dead, just see stale stats).
    StallForMs {
        /// the working cycle on which the stall fires
        cycle: u64,
        /// stall duration in milliseconds
        ms: u64,
    },
    /// `step()` returns `Err` once more than N cycles have run — the
    /// replica loop exits cleanly, which for a remote worker drops the
    /// transport connection without killing the process.
    DropConn(u64),
}

/// The alphabet behind [`mock_tokenizer`]: token 10 decodes to `'h'`,
/// so echo output reads "hijk..." in every session-free test/bench.
pub const MOCK_ALPHABET: &str =
    "abcdefghijklmnopqrstuvwxyz0123456789 \n+-*=?:;,.()<>[]|&%$#@!_";

/// The session-free tokenizer paired with [`EchoEngine`] by the
/// protocol test suites and the pool benches.
pub fn mock_tokenizer() -> Tokenizer {
    Tokenizer::from_alphabet(MOCK_ALPHABET, 64).expect("mock tokenizer")
}

/// Vocab of the toy conditional LM behind the mock's stochastic path
/// (matches [`mock_tokenizer`]).
pub const MOCK_VOCAB: usize = 64;

fn mix(mut h: u64) -> u64 {
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
    h ^= h >> 29;
    h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    h ^ (h >> 32)
}

fn unit(h: u64) -> f32 {
    ((h >> 11) as f64 / (1u64 << 53) as f64) as f32 // [0, 1)
}

/// The mock's "verifier" model: a deterministic first-order toy LM.
/// The logits row after context token `ctx` is a pure hash of
/// `(ctx, v)` — no state, so parallel verification and sequential
/// rollout agree by construction, like a real verify entry.
pub fn mock_logits(ctx: i32) -> Vec<f32> {
    (0..MOCK_VOCAB)
        .map(|v| {
            let h = mix((ctx as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ (v as u64 | (1u64 << 40)));
            6.0 * unit(h) - 3.0
        })
        .collect()
}

/// The mock's "draft" model: the verifier logits plus deterministic
/// per-`(ctx, v)` noise. `acceptance` shapes how far `q` strays from
/// `p` — 1.0 means a perfect draft (noise 0), lower values degrade it
/// (and with it the measured acceptance rate), `None` (plain AR mode,
/// which never drafts) gets a fixed mid-size perturbation.
pub fn mock_draft_logits(ctx: i32, acceptance: Option<f64>) -> Vec<f32> {
    let amp = acceptance.map(|a| 3.0 * (1.0 - a)).unwrap_or(1.5) as f32;
    let mut row = mock_logits(ctx);
    for (v, r) in row.iter_mut().enumerate() {
        let h = mix((ctx as u64).wrapping_mul(0x517c_c1b7_2722_0a95) ^ ((v as u64) << 7) ^ 0xd6e8);
        *r += amp * (2.0 * unit(h) - 1.0);
    }
    row
}

/// Deterministic echo engine over the real `BatchCore` (see module
/// docs). Construct with [`EchoEngine::new`]; tune the scheduling
/// policy / SLO through `core_mut()` like any other engine.
pub struct EchoEngine {
    core: BatchCore,
    step_delay: Duration,
    /// simulated draft-acceptance rate in [0, 1]; `None` = plain AR
    /// echo (never drafts, acceptance reported as null).
    acceptance: Option<f64>,
    /// simulated draft depth; live-tunable via `reconfigure`.
    gamma: usize,
    /// mirrored `kv_bits` from the last `reconfigure` — the mock has no
    /// shadow cache, so this is observability only.
    kv_bits: Option<u8>,
    /// injected fault, if any; counts down against `cycles`.
    failure: Option<FailureMode>,
    /// working scheduling cycles completed (idle waits excluded).
    cycles: u64,
    /// `(width, depth)` when simulating the v1.7 TreeSpec cycle: a real
    /// [`TokenTree`] drafted from the toy draft LM, verified against the
    /// toy verifier, committed through the real tree accept rules.
    tree: Option<(usize, usize)>,
}

impl EchoEngine {
    /// `batch` generation slots over a `max_seq`-deep KV layout, with a
    /// `delay_ms` sleep per scheduling cycle (0 = as fast as possible).
    pub fn new(batch: usize, max_seq: usize, delay_ms: u64) -> Self {
        EchoEngine {
            core: BatchCore::new(
                SlotManager::new(batch, max_seq, 16),
                CostModel::new(Twin::lookup("llama2-7b")),
            ),
            step_delay: Duration::from_millis(delay_ms),
            acceptance: None,
            gamma: MOCK_GAMMA,
            kv_bits: None,
            failure: None,
            cycles: 0,
            tree: None,
        }
    }

    /// Simulate speculative decoding with the given acceptance rate
    /// (clamped to [0, 1]): commits `1 + round(gamma * a)` tokens per
    /// cycle and counts drafted/accepted accordingly.
    pub fn with_acceptance(mut self, a: f64) -> Self {
        self.acceptance = Some(a.clamp(0.0, 1.0));
        self
    }

    /// Arm an injected fault (see [`FailureMode`]); lifecycle tests and
    /// the failover bench kill mock replicas through this.
    pub fn with_failure(mut self, mode: FailureMode) -> Self {
        self.failure = Some(mode);
        self
    }

    /// Simulate the TreeSpec engine session-free: every cycle drafts a
    /// `width`-ary token tree of the given depth from the toy draft LM
    /// ([`mock_draft_logits`]; `with_acceptance` tunes its divergence),
    /// verifies against the toy verifier rows and commits through the
    /// real [`greedy_tree_accept`] / [`stochastic_tree_accept`] rules —
    /// sibling KV branches fork CoW around every accept step, exactly
    /// like the real engine. Greedy output equals a pure argmax rollout
    /// of [`mock_logits`] (tree losslessness, testable byte-for-byte);
    /// stochastic output stays distributed as a `p` rollout.
    pub fn with_tree(mut self, width: usize, depth: usize) -> Self {
        self.tree = Some((width.max(1), depth.max(1)));
        self
    }

    /// Current simulated draft depth (default [`MOCK_GAMMA`]).
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// `kv_bits` from the most recent `reconfigure`, if any.
    pub fn kv_bits(&self) -> Option<u8> {
        self.kv_bits
    }

    /// Working scheduling cycles completed so far.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// One stochastic scheduling cycle for slot `i` (see module docs).
    /// Plain AR mode samples one token from the toy verifier `p`;
    /// acceptance mode drafts `gamma` tokens from the perturbed draft
    /// distribution `q` and runs the stochastic accept rule, so the
    /// committed stream stays distributed as a pure `p` rollout.
    fn step_stochastic_slot(
        &mut self,
        i: usize,
        pending: i32,
        gamma: usize,
        drafting: bool,
        out: &mut Vec<StepEvent>,
    ) {
        let acceptance = self.acceptance;
        let Some(s) = self.core.sampler_mut(i) else { return };
        if !drafting {
            let p = s.probs(&mock_logits(pending));
            let t = s.sample_probs(&p) as i32;
            self.core.commit(i, &[t], 1, out);
            return;
        }
        let mut drafts = Vec::with_capacity(gamma);
        let mut q = Vec::with_capacity(gamma * MOCK_VOCAB);
        let mut cur = pending;
        for _ in 0..gamma {
            let qp = s.probs(&mock_draft_logits(cur, acceptance));
            let d = s.sample_probs(&qp) as i32;
            q.extend_from_slice(&qp);
            drafts.push(d);
            cur = d;
        }
        // verifier distributions at every fed position (a first-order
        // toy LM, so "parallel verification" is just per-context rows)
        let mut p = Vec::with_capacity((gamma + 1) * MOCK_VOCAB);
        let mut prev = pending;
        for j in 0..=gamma {
            p.extend(s.probs(&mock_logits(prev)));
            if j < gamma {
                prev = drafts[j];
            }
        }
        let dec = stochastic_accept(&drafts, &q, &p, MOCK_VOCAB, s);
        self.core.metrics.drafted += gamma as u64;
        self.core.metrics.accepted += dec.accepted as u64;
        self.core.metrics.record_accept(dec.accepted as u64);
        self.core.commit(i, &dec.committed, gamma, out);
    }

    /// One TreeSpec scheduling cycle for slot `i` (see [`with_tree`]):
    /// the full v1.7 engine cycle — multi-branch draft, per-node
    /// verifier rows (the toy LM is first-order, so the row after any
    /// node is just `mock_logits(node token)`, which doubles as the
    /// tree-masked chunk), tree acceptance, CoW branch forks — without
    /// a session.
    ///
    /// [`with_tree`]: EchoEngine::with_tree
    fn step_tree_slot(&mut self, i: usize, pending: i32, out: &mut Vec<StepEvent>) {
        let (width, depth) = self.tree.expect("tree mode");
        let acceptance = self.acceptance;
        let stochastic = self.core.slot_stochastic(i);

        // ---- draft: width candidates per level off the principal chain
        let mut tree = TokenTree::new(width, depth);
        let mut q = Vec::with_capacity(depth * MOCK_VOCAB);
        let mut cur = pending;
        for _ in 0..depth {
            let row = mock_draft_logits(cur, acceptance);
            let cands = if stochastic {
                let s = self.core.sampler_mut(i).expect("stochastic slot");
                let qp = s.probs(&row);
                let cands: Vec<(i32, f32)> = (0..width)
                    .map(|_| {
                        let d = s.sample_probs(&qp);
                        (d as i32, qp[d])
                    })
                    .collect();
                q.extend_from_slice(&qp);
                cands
            } else {
                top_candidates(&row, &softmax(&row), width)
            };
            cur = cands[0].0;
            tree.push_level(&cands);
        }

        // ---- verify + accept
        let mut chain = vec![pending];
        chain.extend(tree.principal_tokens());
        let dec = if stochastic {
            let s = self.core.sampler_mut(i).expect("stochastic slot");
            let mut p = Vec::with_capacity((depth + 1) * MOCK_VOCAB);
            for &c in &chain {
                p.extend(s.probs(&mock_logits(c)));
            }
            let mut tp = Vec::with_capacity(tree.len() * MOCK_VOCAB);
            for node in tree.nodes() {
                tp.extend(s.probs(&mock_logits(node.token)));
            }
            stochastic_tree_accept(&tree, &q, &p, Some(&tp), MOCK_VOCAB, s)
        } else {
            let vt: Vec<i32> = chain.iter().map(|&c| argmax(&mock_logits(c)) as i32).collect();
            let ta: Vec<i32> =
                tree.nodes().iter().map(|n| argmax(&mock_logits(n.token)) as i32).collect();
            greedy_tree_accept(&tree, &vt, Some(&ta))
        };

        // sibling branches fork the slot's block table CoW for the
        // accept step, exactly like the real engine
        let principal = tree.principal_tokens();
        let mut branches = Vec::new();
        for node in tree.nodes().iter().filter(|n| !n.principal) {
            let br = self.core.slots.fork_branch(i);
            for &t in &principal[..node.level] {
                self.core.slots.branch_append(br, t);
            }
            self.core.slots.branch_append(br, node.token);
            branches.push(br);
        }
        self.core.metrics.drafted += depth as u64;
        self.core.metrics.tree_nodes_drafted += tree.len() as u64;
        self.core.metrics.tree_paths += tree.n_paths() as u64;
        self.core.metrics.accepted += dec.accepted as u64;
        self.core.metrics.record_accept(dec.accepted as u64);
        self.core.metrics.accepted_depth.record(dec.accepted as u64);
        for br in branches {
            self.core.slots.release_branch(br);
        }
        debug_assert_eq!(self.core.slots.live_branches(), 0);
        self.core.commit(i, &dec.committed, depth, out);
    }
}

impl Engine for EchoEngine {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn core(&self) -> &BatchCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BatchCore {
        &mut self.core
    }

    fn step(&mut self) -> Result<Vec<StepEvent>> {
        self.cycles += 1;
        match self.failure {
            Some(FailureMode::PanicAfterN(n)) if self.cycles > n => {
                panic!("injected failure: mock replica panicked after {n} cycles")
            }
            Some(FailureMode::StallForMs { cycle, ms }) if self.cycles == cycle => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some(FailureMode::DropConn(n)) if self.cycles > n => {
                return Err(QspecError::Scheduler(format!(
                    "injected failure: mock replica dropped after {n} cycles"
                )));
            }
            _ => {}
        }
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::new();
        if let Some(pb) = self.core.admit_batch(&mut out)? {
            // like the real engines, prefill is priced per *uncached*
            // token — session-free benches and tests can observe the
            // prefix cache's virtual-cost savings
            self.core.cost.charge(
                Mode::W4A16,
                Phase::Chunk,
                pb.admitted.len(),
                pb.uncached_tokens(),
                self.core.slots.prefill_t(),
            );
            let mut first = vec![10i32; self.core.batch()];
            for (idx, req) in &pb.admitted {
                // stochastic slots sample their first token from the
                // toy verifier conditioned on the last prompt token;
                // greedy slots keep the deterministic echo
                if let Some(s) = self.core.sampler_mut(*idx) {
                    let ctx = req.prompt.last().copied().unwrap_or(0);
                    let p = s.probs(&mock_logits(ctx));
                    first[*idx] = s.sample_probs(&p) as i32;
                }
            }
            self.core.finish_prefill(&pb, &first, &mut out);
        }
        if let Some(sb) = self.core.step_inputs() {
            // tokens per cycle: 1 greedy + the simulated accepted drafts
            let gamma = self.gamma;
            let accepted = self
                .acceptance
                .map(|a| (gamma as f64 * a).round() as usize)
                .unwrap_or(0)
                .min(gamma);
            let k = 1 + accepted;
            // the virtual clock must advance every cycle (conformance
            // battery invariant); one batched decode charge per cycle
            self.core.cost.charge(Mode::W4A16, Phase::Decode, sb.active.len(), k, sb.mean_ctx);
            let drafting = self.acceptance.is_some();
            for &i in &sb.active {
                if self.tree.is_some() {
                    self.step_tree_slot(i, sb.tok[i], &mut out);
                    continue;
                }
                if self.core.slot_stochastic(i) {
                    self.step_stochastic_slot(i, sb.tok[i], gamma, drafting, &mut out);
                    continue;
                }
                let toks: Vec<i32> = (1..=k as i32).map(|d| sb.tok[i] + d).collect();
                if drafting {
                    self.core.metrics.drafted += gamma as u64;
                    self.core.metrics.accepted += accepted as u64;
                    self.core.metrics.record_accept(accepted as u64);
                }
                self.core.commit(i, &toks, k, &mut out);
            }
        }
        Ok(out)
    }

    /// The mock serves `temperature > 0` through the real stochastic
    /// accept rule (see module docs), so it is not argmax-only.
    fn argmax_only(&self) -> bool {
        false
    }

    fn reconfigure(&mut self, gamma: Option<usize>, kv_bits: Option<u8>) -> Result<()> {
        if let Some(g) = gamma {
            if !(1..=8).contains(&g) {
                return Err(QspecError::Config(format!("gamma {g} outside 1..=8")));
            }
            self.gamma = g;
        }
        if let Some(b) = kv_bits {
            if !(2..=8).contains(&b) {
                return Err(QspecError::Config(format!("kv_bits {b} outside 2..=8")));
            }
            // no shadow cache to retune in the mock; recorded so tests
            // can observe that the op landed
            self.kv_bits = Some(b);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{FinishReason, GenerationRequest, SamplingParams};

    /// Run one stochastic request to completion; `acc` None = plain AR
    /// echo, Some = acceptance-simulation (drafting) mode.
    fn stochastic_tokens(acc: Option<f64>, seed: u64, n: usize) -> Vec<i32> {
        let mut e = EchoEngine::new(1, 256, 0);
        if let Some(a) = acc {
            e = e.with_acceptance(a);
        }
        let params = SamplingParams {
            max_tokens: n,
            temperature: 0.8,
            seed,
            ..SamplingParams::default()
        };
        e.submit_request(GenerationRequest::new(vec![1, 4, 9], params));
        e.run_to_completion().unwrap().remove(0).tokens
    }

    #[test]
    fn echo_engine_is_deterministic() {
        let run = || {
            let mut e = EchoEngine::new(2, 64, 0);
            e.submit(vec![1, 2], 6);
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(), run());
        assert_eq!(run(), vec![10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn acceptance_simulation_commits_more_per_cycle() {
        let mut ar = EchoEngine::new(1, 256, 0);
        ar.submit(vec![1], 20);
        ar.run_to_completion().unwrap();
        assert!(ar.metrics().acceptance_rate_opt().is_none(), "plain echo never drafts");

        let mut spec = EchoEngine::new(1, 256, 0).with_acceptance(0.75);
        spec.submit(vec![1], 20);
        let fins = spec.run_to_completion().unwrap();
        assert_eq!(fins[0].finish_reason, FinishReason::Length);
        // 0.75 * gamma 4 = 3 accepted -> 4 tokens per cycle; same output
        assert_eq!(fins[0].tokens, (10..30).collect::<Vec<i32>>());
        let acc = spec.metrics().acceptance_rate_opt().expect("drafting engine");
        assert!((acc - 0.75).abs() < 1e-9, "measured acceptance {acc}");
        // fewer cycles than the AR echo for the same budget
        assert!(spec.cost().virtual_ns > 0);
    }

    #[test]
    fn reconfigure_retunes_gamma_live() {
        let mut e = EchoEngine::new(1, 256, 0).with_acceptance(1.0);
        assert_eq!(e.gamma(), MOCK_GAMMA);
        e.reconfigure(Some(2), Some(4)).unwrap();
        assert_eq!(e.gamma(), 2);
        assert_eq!(e.kv_bits(), Some(4));
        e.submit(vec![1], 9);
        e.run_to_completion().unwrap();
        // gamma 2 at full acceptance -> 3 tokens/cycle -> 3 cycles of
        // drafting for 9 tokens (first cycle is the prefill)
        assert_eq!(e.metrics().drafted, 6);
        assert_eq!(e.metrics().accepted, 6);
        assert!(e.reconfigure(Some(0), None).is_err(), "gamma 0 rejected");
        assert!(e.reconfigure(None, Some(16)).is_err(), "kv_bits 16 rejected");
        assert_eq!(e.gamma(), 2, "failed reconfigure must not change state");
    }

    #[test]
    fn mock_serves_temperature_and_is_not_argmax_only() {
        assert!(!EchoEngine::new(1, 64, 0).argmax_only());
        let toks = stochastic_tokens(Some(0.6), 7, 24);
        assert!(!toks.is_empty());
        // sampled stream stays in-vocab (EOS may end it early)
        assert!(toks.iter().all(|&t| (0..MOCK_VOCAB as i32).contains(&t)), "{toks:?}");
    }

    #[test]
    fn stochastic_mock_replays_on_seed_and_diverges_across_seeds() {
        for acc in [None, Some(0.3), Some(0.9)] {
            let a = stochastic_tokens(acc, 7, 24);
            assert_eq!(a, stochastic_tokens(acc, 7, 24), "same seed must replay, acc {acc:?}");
        }
        // across seeds the streams diverge (64-token vocab, 24 draws:
        // a collision over three seeds is astronomically unlikely)
        let runs: Vec<_> = (1..=3).map(|s| stochastic_tokens(Some(0.6), s, 24)).collect();
        assert!(
            runs[0] != runs[1] || runs[1] != runs[2],
            "different seeds should diverge: {runs:?}"
        );
    }

    #[test]
    fn stochastic_and_greedy_slots_coexist_in_one_batch() {
        let mut e = EchoEngine::new(2, 256, 0).with_acceptance(0.6);
        let params = SamplingParams {
            max_tokens: 8,
            temperature: 0.8,
            seed: 11,
            ..SamplingParams::default()
        };
        let sid = e.submit_request(GenerationRequest::new(vec![1, 4, 9], params));
        let gid = e.submit(vec![1, 2], 6);
        let fins = e.run_to_completion().unwrap();
        let greedy = fins.iter().find(|f| f.id == gid).unwrap();
        assert_eq!(greedy.tokens, vec![10, 11, 12, 13, 14, 15], "greedy echo unchanged");
        let stoch = fins.iter().find(|f| f.id == sid).unwrap();
        assert_eq!(stoch.tokens, stochastic_tokens(Some(0.6), 11, 8),
                   "per-slot sampler is batch-placement independent");
        // drafted/accepted counters cover the stochastic slot too
        assert!(e.metrics().drafted > 0);
    }

    #[test]
    fn tree_mode_commits_the_verifier_argmax_rollout() {
        // tree losslessness: whatever the tree accepts, the greedy
        // committed stream must be byte-identical to a pure argmax
        // rollout of the toy verifier from the prefill token
        let mut e = EchoEngine::new(1, 256, 0).with_tree(2, 3).with_acceptance(0.7);
        e.submit(vec![1, 2], 12);
        let fins = e.run_to_completion().unwrap();
        let got = &fins[0].tokens;
        let mut want = vec![10i32];
        while want.len() < got.len() {
            want.push(argmax(&mock_logits(*want.last().unwrap())) as i32);
        }
        assert_eq!(got, &want, "tree acceptance changed the greedy stream");
        assert!(e.metrics().tree_nodes_drafted > 0, "v1.7 stats populated");
        assert!(e.metrics().tree_paths > 0);
        assert!(e.metrics().accepted_depth.count() > 0);
        assert_eq!(e.core().slots.live_branches(), 0, "all branches released");
    }

    #[test]
    fn tree_mode_width_one_matches_linear_argmax_rollout() {
        // width 1 is the linear degenerate: same rollout, fewer nodes
        let run = |w: usize| {
            let mut e = EchoEngine::new(1, 256, 0).with_tree(w, 3).with_acceptance(0.7);
            e.submit(vec![1, 2], 10);
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(1), run(3), "committed stream is width-invariant under greedy");
    }

    #[test]
    fn stochastic_tree_mode_replays_on_seed_and_diverges_across_seeds() {
        let run = |seed: u64| {
            let mut e = EchoEngine::new(1, 256, 0).with_tree(2, 3).with_acceptance(0.6);
            let params = SamplingParams {
                max_tokens: 16,
                temperature: 0.9,
                seed,
                ..SamplingParams::default()
            };
            e.submit_request(GenerationRequest::new(vec![1, 4, 9], params));
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(5), run(5), "same seed must replay");
        let runs: Vec<_> = (1..=3).map(run).collect();
        assert!(runs[0] != runs[1] || runs[1] != runs[2], "seeds should diverge: {runs:?}");
    }

    #[test]
    fn drop_conn_failure_errors_after_n_cycles() {
        let mut e = EchoEngine::new(1, 64, 0).with_failure(FailureMode::DropConn(2));
        e.submit(vec![1], 32);
        assert!(e.step().is_ok(), "cycle 1 works");
        assert!(e.step().is_ok(), "cycle 2 works");
        let err = e.step().expect_err("cycle 3 trips the injected drop");
        assert!(err.to_string().contains("injected failure"), "got: {err}");
        assert_eq!(e.cycles(), 3);
    }

    #[test]
    #[should_panic(expected = "injected failure")]
    fn panic_failure_panics_after_n_cycles() {
        let mut e = EchoEngine::new(1, 64, 0).with_failure(FailureMode::PanicAfterN(1));
        e.submit(vec![1], 32);
        let _ = e.step();
        let _ = e.step();
    }

    #[test]
    fn stall_failure_sleeps_once_then_recovers() {
        let mut e = EchoEngine::new(1, 64, 0)
            .with_failure(FailureMode::StallForMs { cycle: 2, ms: 30 });
        e.submit(vec![1], 6);
        let t0 = std::time::Instant::now();
        let fins = e.run_to_completion().unwrap();
        assert_eq!(fins[0].tokens, vec![10, 11, 12, 13, 14, 15], "output unchanged");
        assert!(t0.elapsed() >= Duration::from_millis(30), "stall observed");
    }
}
