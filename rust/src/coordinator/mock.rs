//! Session-free mock engine: deterministic echo decoding over the real
//! [`BatchCore`], no artifacts or PJRT session required.
//!
//! Prefill emits token 10; each scheduling cycle commits `pending + 1,
//! pending + 2, ...` so the output text is deterministic ("hijk..."
//! under the test alphabet) and streaming/stop/cancel semantics are
//! fully exercised. Two knobs shape it into a pool replica stand-in:
//!
//! * `step_delay` — per-cycle sleep, widening cancellation race
//!   windows and letting benches model slow replicas;
//! * `with_acceptance(a)` — simulate a drafting engine: every cycle
//!   drafts `gamma` tokens, accepts `round(gamma * a)` of them, and
//!   commits `1 + accepted` tokens. Acceptance shows up in
//!   `metrics.drafted/accepted` (so `acceptance_rate ~= a`) *and* in
//!   throughput (more tokens per fixed-delay cycle), which is exactly
//!   the signal the pool's `acceptance_aware` route policy bets on.
//!
//! For the v1.4 lifecycle layer (transport failover, respawn,
//! autoscaling) the mock grows two more knobs: [`FailureMode`] fault
//! injection (panic, stall, or clean error after N working cycles, so
//! replica-death paths are reachable without killing processes) and a
//! settable draft depth via [`Engine::reconfigure`], making the
//! router's live `reconfigure` op observable session-free.
//!
//! The protocol test suites and `benches/pool_router.rs` build mock
//! replica pools from this engine; `tests/engine_trait.rs` runs it
//! through the same conformance battery as the real engines.

use std::time::Duration;

use crate::costmodel::{twins::Twin, CostModel, Phase};
use crate::error::{QspecError, Result};
use crate::kvcache::SlotManager;
use crate::model::{Mode, Tokenizer};

use super::engine::{BatchCore, Engine};
use super::request::StepEvent;

/// Default draft depth of the simulated speculative mode (retunable
/// per engine instance through [`Engine::reconfigure`]).
pub const MOCK_GAMMA: usize = 4;

/// Injected fault for lifecycle tests and failover benches: all three
/// modes count *working* scheduling cycles (idle waits don't step the
/// engine), so `PanicAfterN(3)` fires on the 4th cycle that actually
/// processes work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureMode {
    /// `panic!` in `step()` once more than N cycles have run — models a
    /// replica thread/process dying hard (the channel closes, a remote
    /// worker's socket drops without a goodbye).
    PanicAfterN(u64),
    /// One-time `sleep(ms)` on exactly cycle N — models a wedged or
    /// GC-pausing replica that is still alive (heartbeats keep flowing;
    /// the router must *not* declare it dead, just see stale stats).
    StallForMs {
        /// the working cycle on which the stall fires
        cycle: u64,
        /// stall duration in milliseconds
        ms: u64,
    },
    /// `step()` returns `Err` once more than N cycles have run — the
    /// replica loop exits cleanly, which for a remote worker drops the
    /// transport connection without killing the process.
    DropConn(u64),
}

/// The alphabet behind [`mock_tokenizer`]: token 10 decodes to `'h'`,
/// so echo output reads "hijk..." in every session-free test/bench.
pub const MOCK_ALPHABET: &str =
    "abcdefghijklmnopqrstuvwxyz0123456789 \n+-*=?:;,.()<>[]|&%$#@!_";

/// The session-free tokenizer paired with [`EchoEngine`] by the
/// protocol test suites and the pool benches.
pub fn mock_tokenizer() -> Tokenizer {
    Tokenizer::from_alphabet(MOCK_ALPHABET, 64).expect("mock tokenizer")
}

/// Deterministic echo engine over the real `BatchCore` (see module
/// docs). Construct with [`EchoEngine::new`]; tune the scheduling
/// policy / SLO through `core_mut()` like any other engine.
pub struct EchoEngine {
    core: BatchCore,
    step_delay: Duration,
    /// simulated draft-acceptance rate in [0, 1]; `None` = plain AR
    /// echo (never drafts, acceptance reported as null).
    acceptance: Option<f64>,
    /// simulated draft depth; live-tunable via `reconfigure`.
    gamma: usize,
    /// mirrored `kv_bits` from the last `reconfigure` — the mock has no
    /// shadow cache, so this is observability only.
    kv_bits: Option<u8>,
    /// injected fault, if any; counts down against `cycles`.
    failure: Option<FailureMode>,
    /// working scheduling cycles completed (idle waits excluded).
    cycles: u64,
}

impl EchoEngine {
    /// `batch` generation slots over a `max_seq`-deep KV layout, with a
    /// `delay_ms` sleep per scheduling cycle (0 = as fast as possible).
    pub fn new(batch: usize, max_seq: usize, delay_ms: u64) -> Self {
        EchoEngine {
            core: BatchCore::new(
                SlotManager::new(batch, max_seq, 16),
                CostModel::new(Twin::lookup("llama2-7b")),
            ),
            step_delay: Duration::from_millis(delay_ms),
            acceptance: None,
            gamma: MOCK_GAMMA,
            kv_bits: None,
            failure: None,
            cycles: 0,
        }
    }

    /// Simulate speculative decoding with the given acceptance rate
    /// (clamped to [0, 1]): commits `1 + round(gamma * a)` tokens per
    /// cycle and counts drafted/accepted accordingly.
    pub fn with_acceptance(mut self, a: f64) -> Self {
        self.acceptance = Some(a.clamp(0.0, 1.0));
        self
    }

    /// Arm an injected fault (see [`FailureMode`]); lifecycle tests and
    /// the failover bench kill mock replicas through this.
    pub fn with_failure(mut self, mode: FailureMode) -> Self {
        self.failure = Some(mode);
        self
    }

    /// Current simulated draft depth (default [`MOCK_GAMMA`]).
    pub fn gamma(&self) -> usize {
        self.gamma
    }

    /// `kv_bits` from the most recent `reconfigure`, if any.
    pub fn kv_bits(&self) -> Option<u8> {
        self.kv_bits
    }

    /// Working scheduling cycles completed so far.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }
}

impl Engine for EchoEngine {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn core(&self) -> &BatchCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BatchCore {
        &mut self.core
    }

    fn step(&mut self) -> Result<Vec<StepEvent>> {
        self.cycles += 1;
        match self.failure {
            Some(FailureMode::PanicAfterN(n)) if self.cycles > n => {
                panic!("injected failure: mock replica panicked after {n} cycles")
            }
            Some(FailureMode::StallForMs { cycle, ms }) if self.cycles == cycle => {
                std::thread::sleep(Duration::from_millis(ms));
            }
            Some(FailureMode::DropConn(n)) if self.cycles > n => {
                return Err(QspecError::Scheduler(format!(
                    "injected failure: mock replica dropped after {n} cycles"
                )));
            }
            _ => {}
        }
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::new();
        if let Some(pb) = self.core.admit_batch(&mut out)? {
            // like the real engines, prefill is priced per *uncached*
            // token — session-free benches and tests can observe the
            // prefix cache's virtual-cost savings
            self.core.cost.charge(
                Mode::W4A16,
                Phase::Chunk,
                pb.admitted.len(),
                pb.uncached_tokens(),
                self.core.slots.prefill_t(),
            );
            let first = vec![10i32; self.core.batch()];
            self.core.finish_prefill(&pb, &first, &mut out);
        }
        if let Some(sb) = self.core.step_inputs() {
            // tokens per cycle: 1 greedy + the simulated accepted drafts
            let gamma = self.gamma;
            let accepted = self
                .acceptance
                .map(|a| (gamma as f64 * a).round() as usize)
                .unwrap_or(0)
                .min(gamma);
            let k = 1 + accepted;
            // the virtual clock must advance every cycle (conformance
            // battery invariant); one batched decode charge per cycle
            self.core.cost.charge(Mode::W4A16, Phase::Decode, sb.active.len(), k, sb.mean_ctx);
            for &i in &sb.active {
                let toks: Vec<i32> = (1..=k as i32).map(|d| sb.tok[i] + d).collect();
                if self.acceptance.is_some() {
                    self.core.metrics.drafted += gamma as u64;
                    self.core.metrics.accepted += accepted as u64;
                    self.core.metrics.record_accept(accepted as u64);
                }
                self.core.commit(i, &toks, k, &mut out);
            }
        }
        Ok(out)
    }

    fn reconfigure(&mut self, gamma: Option<usize>, kv_bits: Option<u8>) -> Result<()> {
        if let Some(g) = gamma {
            if !(1..=8).contains(&g) {
                return Err(QspecError::Config(format!("gamma {g} outside 1..=8")));
            }
            self.gamma = g;
        }
        if let Some(b) = kv_bits {
            if !(2..=8).contains(&b) {
                return Err(QspecError::Config(format!("kv_bits {b} outside 2..=8")));
            }
            // no shadow cache to retune in the mock; recorded so tests
            // can observe that the op landed
            self.kv_bits = Some(b);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn echo_engine_is_deterministic() {
        let run = || {
            let mut e = EchoEngine::new(2, 64, 0);
            e.submit(vec![1, 2], 6);
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(), run());
        assert_eq!(run(), vec![10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn acceptance_simulation_commits_more_per_cycle() {
        let mut ar = EchoEngine::new(1, 256, 0);
        ar.submit(vec![1], 20);
        ar.run_to_completion().unwrap();
        assert!(ar.metrics().acceptance_rate_opt().is_none(), "plain echo never drafts");

        let mut spec = EchoEngine::new(1, 256, 0).with_acceptance(0.75);
        spec.submit(vec![1], 20);
        let fins = spec.run_to_completion().unwrap();
        assert_eq!(fins[0].finish_reason, FinishReason::Length);
        // 0.75 * gamma 4 = 3 accepted -> 4 tokens per cycle; same output
        assert_eq!(fins[0].tokens, (10..30).collect::<Vec<i32>>());
        let acc = spec.metrics().acceptance_rate_opt().expect("drafting engine");
        assert!((acc - 0.75).abs() < 1e-9, "measured acceptance {acc}");
        // fewer cycles than the AR echo for the same budget
        assert!(spec.cost().virtual_ns > 0);
    }

    #[test]
    fn reconfigure_retunes_gamma_live() {
        let mut e = EchoEngine::new(1, 256, 0).with_acceptance(1.0);
        assert_eq!(e.gamma(), MOCK_GAMMA);
        e.reconfigure(Some(2), Some(4)).unwrap();
        assert_eq!(e.gamma(), 2);
        assert_eq!(e.kv_bits(), Some(4));
        e.submit(vec![1], 9);
        e.run_to_completion().unwrap();
        // gamma 2 at full acceptance -> 3 tokens/cycle -> 3 cycles of
        // drafting for 9 tokens (first cycle is the prefill)
        assert_eq!(e.metrics().drafted, 6);
        assert_eq!(e.metrics().accepted, 6);
        assert!(e.reconfigure(Some(0), None).is_err(), "gamma 0 rejected");
        assert!(e.reconfigure(None, Some(16)).is_err(), "kv_bits 16 rejected");
        assert_eq!(e.gamma(), 2, "failed reconfigure must not change state");
    }

    #[test]
    fn drop_conn_failure_errors_after_n_cycles() {
        let mut e = EchoEngine::new(1, 64, 0).with_failure(FailureMode::DropConn(2));
        e.submit(vec![1], 32);
        assert!(e.step().is_ok(), "cycle 1 works");
        assert!(e.step().is_ok(), "cycle 2 works");
        let err = e.step().expect_err("cycle 3 trips the injected drop");
        assert!(err.to_string().contains("injected failure"), "got: {err}");
        assert_eq!(e.cycles(), 3);
    }

    #[test]
    #[should_panic(expected = "injected failure")]
    fn panic_failure_panics_after_n_cycles() {
        let mut e = EchoEngine::new(1, 64, 0).with_failure(FailureMode::PanicAfterN(1));
        e.submit(vec![1], 32);
        let _ = e.step();
        let _ = e.step();
    }

    #[test]
    fn stall_failure_sleeps_once_then_recovers() {
        let mut e = EchoEngine::new(1, 64, 0)
            .with_failure(FailureMode::StallForMs { cycle: 2, ms: 30 });
        e.submit(vec![1], 6);
        let t0 = std::time::Instant::now();
        let fins = e.run_to_completion().unwrap();
        assert_eq!(fins[0].tokens, vec![10, 11, 12, 13, 14, 15], "output unchanged");
        assert!(t0.elapsed() >= Duration::from_millis(30), "stall observed");
    }
}
