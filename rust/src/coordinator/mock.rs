//! Session-free mock engine: deterministic echo decoding over the real
//! [`BatchCore`], no artifacts or PJRT session required.
//!
//! Prefill emits token 10; each scheduling cycle commits `pending + 1,
//! pending + 2, ...` so the output text is deterministic ("hijk..."
//! under the test alphabet) and streaming/stop/cancel semantics are
//! fully exercised. Two knobs shape it into a pool replica stand-in:
//!
//! * `step_delay` — per-cycle sleep, widening cancellation race
//!   windows and letting benches model slow replicas;
//! * `with_acceptance(a)` — simulate a drafting engine: every cycle
//!   drafts `gamma` tokens, accepts `round(gamma * a)` of them, and
//!   commits `1 + accepted` tokens. Acceptance shows up in
//!   `metrics.drafted/accepted` (so `acceptance_rate ~= a`) *and* in
//!   throughput (more tokens per fixed-delay cycle), which is exactly
//!   the signal the pool's `acceptance_aware` route policy bets on.
//!
//! The protocol test suites and `benches/pool_router.rs` build mock
//! replica pools from this engine; `tests/engine_trait.rs` runs it
//! through the same conformance battery as the real engines.

use std::time::Duration;

use crate::costmodel::{twins::Twin, CostModel, Phase};
use crate::error::Result;
use crate::kvcache::SlotManager;
use crate::model::{Mode, Tokenizer};

use super::engine::{BatchCore, Engine};
use super::request::StepEvent;

/// Draft depth of the simulated speculative mode.
const MOCK_GAMMA: usize = 4;

/// The alphabet behind [`mock_tokenizer`]: token 10 decodes to `'h'`,
/// so echo output reads "hijk..." in every session-free test/bench.
pub const MOCK_ALPHABET: &str =
    "abcdefghijklmnopqrstuvwxyz0123456789 \n+-*=?:;,.()<>[]|&%$#@!_";

/// The session-free tokenizer paired with [`EchoEngine`] by the
/// protocol test suites and the pool benches.
pub fn mock_tokenizer() -> Tokenizer {
    Tokenizer::from_alphabet(MOCK_ALPHABET, 64).expect("mock tokenizer")
}

/// Deterministic echo engine over the real `BatchCore` (see module
/// docs). Construct with [`EchoEngine::new`]; tune the scheduling
/// policy / SLO through `core_mut()` like any other engine.
pub struct EchoEngine {
    core: BatchCore,
    step_delay: Duration,
    /// simulated draft-acceptance rate in [0, 1]; `None` = plain AR
    /// echo (never drafts, acceptance reported as null).
    acceptance: Option<f64>,
}

impl EchoEngine {
    /// `batch` generation slots over a `max_seq`-deep KV layout, with a
    /// `delay_ms` sleep per scheduling cycle (0 = as fast as possible).
    pub fn new(batch: usize, max_seq: usize, delay_ms: u64) -> Self {
        EchoEngine {
            core: BatchCore::new(
                SlotManager::new(batch, max_seq, 16),
                CostModel::new(Twin::lookup("llama2-7b")),
            ),
            step_delay: Duration::from_millis(delay_ms),
            acceptance: None,
        }
    }

    /// Simulate speculative decoding with the given acceptance rate
    /// (clamped to [0, 1]): commits `1 + round(gamma * a)` tokens per
    /// cycle and counts drafted/accepted accordingly.
    pub fn with_acceptance(mut self, a: f64) -> Self {
        self.acceptance = Some(a.clamp(0.0, 1.0));
        self
    }
}

impl Engine for EchoEngine {
    fn name(&self) -> &'static str {
        "mock"
    }

    fn core(&self) -> &BatchCore {
        &self.core
    }

    fn core_mut(&mut self) -> &mut BatchCore {
        &mut self.core
    }

    fn step(&mut self) -> Result<Vec<StepEvent>> {
        if !self.step_delay.is_zero() {
            std::thread::sleep(self.step_delay);
        }
        let mut out = Vec::new();
        if let Some(pb) = self.core.admit_batch(&mut out)? {
            // like the real engines, prefill is priced per *uncached*
            // token — session-free benches and tests can observe the
            // prefix cache's virtual-cost savings
            self.core.cost.charge(
                Mode::W4A16,
                Phase::Chunk,
                pb.admitted.len(),
                pb.uncached_tokens(),
                self.core.slots.prefill_t(),
            );
            let first = vec![10i32; self.core.batch()];
            self.core.finish_prefill(&pb, &first, &mut out);
        }
        if let Some(sb) = self.core.step_inputs() {
            // tokens per cycle: 1 greedy + the simulated accepted drafts
            let accepted = self
                .acceptance
                .map(|a| (MOCK_GAMMA as f64 * a).round() as usize)
                .unwrap_or(0)
                .min(MOCK_GAMMA);
            let k = 1 + accepted;
            // the virtual clock must advance every cycle (conformance
            // battery invariant); one batched decode charge per cycle
            self.core.cost.charge(Mode::W4A16, Phase::Decode, sb.active.len(), k, sb.mean_ctx);
            for &i in &sb.active {
                let toks: Vec<i32> = (1..=k as i32).map(|d| sb.tok[i] + d).collect();
                if self.acceptance.is_some() {
                    self.core.metrics.drafted += MOCK_GAMMA as u64;
                    self.core.metrics.accepted += accepted as u64;
                }
                self.core.commit(i, &toks, k, &mut out);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::FinishReason;

    #[test]
    fn echo_engine_is_deterministic() {
        let run = || {
            let mut e = EchoEngine::new(2, 64, 0);
            e.submit(vec![1, 2], 6);
            e.run_to_completion().unwrap().remove(0).tokens
        };
        assert_eq!(run(), run());
        assert_eq!(run(), vec![10, 11, 12, 13, 14, 15]);
    }

    #[test]
    fn acceptance_simulation_commits_more_per_cycle() {
        let mut ar = EchoEngine::new(1, 256, 0);
        ar.submit(vec![1], 20);
        ar.run_to_completion().unwrap();
        assert!(ar.metrics().acceptance_rate_opt().is_none(), "plain echo never drafts");

        let mut spec = EchoEngine::new(1, 256, 0).with_acceptance(0.75);
        spec.submit(vec![1], 20);
        let fins = spec.run_to_completion().unwrap();
        assert_eq!(fins[0].finish_reason, FinishReason::Length);
        // 0.75 * gamma 4 = 3 accepted -> 4 tokens per cycle; same output
        assert_eq!(fins[0].tokens, (10..30).collect::<Vec<i32>>());
        let acc = spec.metrics().acceptance_rate_opt().expect("drafting engine");
        assert!((acc - 0.75).abs() < 1e-9, "measured acceptance {acc}");
        // fewer cycles than the AR echo for the same budget
        assert!(spec.cost().virtual_ns > 0);
    }
}
