//! Serving metrics: per-phase wall/virtual timers, acceptance counters,
//! request latency tracking, and report emission (paper figures 4/5 and
//! the throughput tables are computed from these).

use std::time::Instant;

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::stats::{LogHistogram, Summary};

/// Phases of the speculative serving loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PhaseKind {
    Prefill,
    Draft,
    Verify,
    Decode,
    Host,
}

impl PhaseKind {
    pub fn name(self) -> &'static str {
        match self {
            PhaseKind::Prefill => "prefill",
            PhaseKind::Draft => "draft",
            PhaseKind::Verify => "verify",
            PhaseKind::Decode => "decode",
            PhaseKind::Host => "host",
        }
    }

    const ALL: [PhaseKind; 5] = [
        PhaseKind::Prefill,
        PhaseKind::Draft,
        PhaseKind::Verify,
        PhaseKind::Decode,
        PhaseKind::Host,
    ];
}

/// Aggregated engine metrics for one run.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// wall ns per phase
    pub wall_ns: [u128; 5],
    /// virtual (cost-model) ns per phase
    pub virt_ns: [u128; 5],
    /// calls per phase
    pub calls: [u64; 5],
    /// tokens drafted / accepted / committed (incl. bonus)
    pub drafted: u64,
    pub accepted: u64,
    pub committed: u64,
    /// finished requests + generated token total
    pub requests_done: u64,
    pub tokens_out: u64,
    /// requests cancelled mid-flight (explicit op or client disconnect);
    /// excluded from `requests_done` and the latency histogram.
    pub cancelled: u64,
    /// requests rejected at submission by the admission SLO (the
    /// `overloaded` frame); they never enter the queue.
    pub shed: u64,
    /// requests whose deadline had already lapsed when a slot would
    /// have admitted them (`FinishReason::DeadlineExceeded`); they
    /// waited in the queue but never ran, so they count in `queue_wait`
    /// only.
    pub deadline_expired: u64,
    /// prefix-cache lookups at admission (one per admitted request
    /// when the cache is enabled; 0 means the cache is off).
    pub prefix_queries: u64,
    /// prompt tokens covered by prefix-cache matches — prefill compute
    /// skipped by attaching committed blocks instead of recomputing.
    pub prefix_hit_tokens: u64,
    /// per-request end-to-end latency (wall ns)
    pub req_latency: LogHistogram,
    /// per-request queue wait (submit -> admission, wall ns)
    pub queue_wait: LogHistogram,
    /// per-cycle accepted-length summary
    pub accept_len: Summary,
    /// per-cycle accepted-length *distribution* (log-bucketed): the
    /// summary above carries mean/std, this carries the shape — what
    /// fraction of cycles accepted 0, 1, ..., gamma drafts — for the
    /// Prometheus export and the acceptance-tuning loops.
    pub accept_hist: LogHistogram,
    /// v1.7 (TreeSpec only): total tree nodes drafted — principal chain
    /// plus sibling candidates (`drafted` counts the chain alone, so
    /// `tree_nodes_drafted - drafted` is the sibling overdraft).
    pub tree_nodes_drafted: u64,
    /// v1.7 (TreeSpec only): total root-paths (tree leaves) drafted.
    pub tree_paths: u64,
    /// v1.7 (TreeSpec only): per-cycle accepted root-path depth
    /// distribution — how deep the committed path reached.
    pub accepted_depth: LogHistogram,
}

impl EngineMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    fn idx(p: PhaseKind) -> usize {
        PhaseKind::ALL.iter().position(|&x| x == p).unwrap()
    }

    /// Record one verify cycle's accepted-draft count in both the
    /// summary (mean/std) and the distribution histogram. The engines'
    /// acceptance loops call this instead of touching `accept_len`
    /// directly so the two views can never drift apart.
    pub fn record_accept(&mut self, accepted: u64) {
        self.accept_len.add(accepted as f64);
        self.accept_hist.record(accepted);
    }

    pub fn add_phase(&mut self, p: PhaseKind, wall_ns: u128, virt_ns: u128) {
        let i = Self::idx(p);
        self.wall_ns[i] += wall_ns;
        self.virt_ns[i] += virt_ns;
        self.calls[i] += 1;
    }

    pub fn wall_total_ns(&self) -> u128 {
        self.wall_ns.iter().sum()
    }

    pub fn virt_total_ns(&self) -> u128 {
        self.virt_ns.iter().sum()
    }

    /// Token acceptance rate (accepted drafts / drafted). 0.0 when the
    /// engine never drafted — prefer [`Self::acceptance_rate_opt`] for
    /// reporting, which distinguishes "no drafting" from "0% accepted".
    pub fn acceptance_rate(&self) -> f64 {
        self.acceptance_rate_opt().unwrap_or(0.0)
    }

    /// Acceptance rate, or `None` for engines that never drafted
    /// (plain AR): JSON surfaces emit `null` instead of a misleading
    /// 0.0 that reads as "every draft rejected".
    pub fn acceptance_rate_opt(&self) -> Option<f64> {
        if self.drafted == 0 {
            return None;
        }
        Some(self.accepted as f64 / self.drafted as f64)
    }

    /// Mean prefix-cache hit tokens per lookup, or `None` when the
    /// cache never ran a lookup (cache disabled, or no admissions
    /// yet) — same null convention as [`Self::acceptance_rate_opt`].
    pub fn prefix_hit_rate_opt(&self) -> Option<f64> {
        if self.prefix_queries == 0 {
            return None;
        }
        Some(self.prefix_hit_tokens as f64 / self.prefix_queries as f64)
    }

    /// Wall-clock generation throughput (token/s).
    pub fn wall_tokens_per_s(&self) -> f64 {
        let t = self.wall_total_ns();
        if t == 0 {
            return 0.0;
        }
        self.tokens_out as f64 * 1e9 / t as f64
    }

    /// Virtual (paper-scale) throughput (token/s).
    pub fn virt_tokens_per_s(&self) -> f64 {
        let t = self.virt_total_ns();
        if t == 0 {
            return 0.0;
        }
        self.tokens_out as f64 * 1e9 / t as f64
    }

    /// Per-valid-token latency decomposition (fig 4): (phase, wall ns/token,
    /// virtual ns/token).
    pub fn per_token_decomposition(&self) -> Vec<(&'static str, f64, f64)> {
        let toks = self.tokens_out.max(1) as f64;
        PhaseKind::ALL
            .iter()
            .map(|&p| {
                let i = Self::idx(p);
                (p.name(), self.wall_ns[i] as f64 / toks, self.virt_ns[i] as f64 / toks)
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let phases = PhaseKind::ALL
            .iter()
            .map(|&p| {
                let i = Self::idx(p);
                obj(vec![
                    ("phase", s(p.name())),
                    ("wall_ns", num(self.wall_ns[i] as f64)),
                    ("virt_ns", num(self.virt_ns[i] as f64)),
                    ("calls", num(self.calls[i] as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("phases", arr(phases)),
            ("drafted", num(self.drafted as f64)),
            ("accepted", num(self.accepted as f64)),
            ("committed", num(self.committed as f64)),
            ("requests_done", num(self.requests_done as f64)),
            ("tokens_out", num(self.tokens_out as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("shed", num(self.shed as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("prefix_queries", num(self.prefix_queries as f64)),
            ("prefix_hit_tokens", num(self.prefix_hit_tokens as f64)),
            // null (not 0.0) when the cache never ran a lookup
            ("prefix_hit_rate", self.prefix_hit_rate_opt().map_or(Json::Null, num)),
            // null (not 0.0) when the engine never drafted
            ("acceptance_rate", self.acceptance_rate_opt().map_or(Json::Null, num)),
            ("wall_tok_s", num(self.wall_tokens_per_s())),
            ("virt_tok_s", num(self.virt_tokens_per_s())),
            ("tree_nodes_drafted", num(self.tree_nodes_drafted as f64)),
            ("tree_paths", num(self.tree_paths as f64)),
            ("latency_p50_ns", num(self.req_latency.percentile(50.0) as f64)),
            ("latency_p99_ns", num(self.req_latency.percentile(99.0) as f64)),
            ("queue_p50_ns", num(self.queue_wait.percentile(50.0) as f64)),
            ("queue_p99_ns", num(self.queue_wait.percentile(99.0) as f64)),
        ])
    }
}

/// Scoped phase timer.
pub struct PhaseTimer {
    start: Instant,
}

impl PhaseTimer {
    pub fn start() -> Self {
        PhaseTimer { start: Instant::now() }
    }

    pub fn elapsed_ns(&self) -> u128 {
        self.start.elapsed().as_nanos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acceptance_rate() {
        let mut m = EngineMetrics::new();
        m.drafted = 10;
        m.accepted = 8;
        assert!((m.acceptance_rate() - 0.8).abs() < 1e-9);
        assert!((m.acceptance_rate_opt().unwrap() - 0.8).abs() < 1e-9);
    }

    #[test]
    fn acceptance_rate_is_null_not_zero_when_never_drafted() {
        let m = EngineMetrics::new();
        assert!(m.acceptance_rate_opt().is_none());
        assert_eq!(m.acceptance_rate(), 0.0);
        // JSON reports null, never a misleading 0.0
        assert_eq!(m.to_json().get("acceptance_rate"), Some(&Json::Null));
        let mut m = EngineMetrics::new();
        m.drafted = 4;
        m.accepted = 0;
        // a drafting engine with 0% acceptance still reports the number
        assert_eq!(m.to_json().get("acceptance_rate"), Some(&num(0.0)));
    }

    #[test]
    fn throughput_from_phases() {
        let mut m = EngineMetrics::new();
        m.add_phase(PhaseKind::Draft, 500_000_000, 1_000_000);
        m.add_phase(PhaseKind::Verify, 500_000_000, 1_000_000);
        m.tokens_out = 100;
        assert!((m.wall_tokens_per_s() - 100.0).abs() < 1e-6);
        assert!((m.virt_tokens_per_s() - 50_000.0).abs() < 1e-3);
    }

    #[test]
    fn decomposition_covers_phases() {
        let mut m = EngineMetrics::new();
        m.tokens_out = 10;
        m.add_phase(PhaseKind::Draft, 100, 200);
        let d = m.per_token_decomposition();
        assert_eq!(d.len(), 5);
        assert_eq!(d[1].0, "draft");
        assert!((d[1].1 - 10.0).abs() < 1e-9);
    }

    #[test]
    fn json_has_expected_fields() {
        let j = EngineMetrics::new().to_json();
        assert!(j.get("acceptance_rate").is_some());
        assert!(j.get("phases").unwrap().as_arr().unwrap().len() == 5);
        assert!(j.get("queue_p50_ns").is_some());
        assert!(j.get("cancelled").is_some());
        assert!(j.get("shed").is_some());
        assert!(j.get("deadline_expired").is_some());
        assert!(j.get("prefix_queries").is_some());
        assert!(j.get("prefix_hit_tokens").is_some());
        assert!(j.get("tree_nodes_drafted").is_some());
        assert!(j.get("tree_paths").is_some());
    }

    #[test]
    fn prefix_hit_rate_is_null_until_first_lookup() {
        let m = EngineMetrics::new();
        assert!(m.prefix_hit_rate_opt().is_none());
        assert_eq!(m.to_json().get("prefix_hit_rate"), Some(&Json::Null));
        let mut m = EngineMetrics::new();
        m.prefix_queries = 4;
        m.prefix_hit_tokens = 32;
        assert_eq!(m.prefix_hit_rate_opt(), Some(8.0));
        // an enabled cache with no hits still reports the number
        m.prefix_hit_tokens = 0;
        assert_eq!(m.to_json().get("prefix_hit_rate"), Some(&num(0.0)));
    }

    #[test]
    fn record_accept_feeds_summary_and_histogram() {
        let mut m = EngineMetrics::new();
        for a in [0u64, 2, 2, 4] {
            m.record_accept(a);
        }
        assert_eq!(m.accept_len.count(), 4);
        assert!((m.accept_len.mean() - 2.0).abs() < 1e-9);
        assert_eq!(m.accept_hist.count(), 4);
        let total: u64 = m.accept_hist.nonzero_buckets().map(|(_, c)| c).sum();
        assert_eq!(total, 4);
    }

    #[test]
    fn queue_wait_recorded_independently_of_latency() {
        let mut m = EngineMetrics::new();
        m.queue_wait.record(1_000);
        m.queue_wait.record(2_000);
        m.req_latency.record(50_000);
        assert_eq!(m.queue_wait.count(), 2);
        assert_eq!(m.req_latency.count(), 1);
        assert!(m.queue_wait.percentile(50.0) < m.req_latency.percentile(50.0));
    }
}
