//! Ring-buffer tracing core: spans (RAII start/end pairs) and instant
//! events over a bounded, lock-cheap ring.
//!
//! Design constraints, in order:
//!
//! 1. **Disabled tracing must cost nothing on the hot path** — one
//!    relaxed atomic load, no allocation, no lock. The engines call
//!    into this once per scheduling phase and once per request
//!    lifecycle transition, so anything heavier would show up in the
//!    `benches/obs_overhead.rs` race.
//! 2. **Bounded memory** — the ring holds the last `capacity` events
//!    and drops the oldest beyond that (counting the drops). This is
//!    what makes the ring double as the flight recorder: it always
//!    holds the most recent history, never grows, and a snapshot is
//!    one lock + clone.
//! 3. **Panic-safe** — a replica thread that panics mid-span must not
//!    poison the ring (the panic path is exactly when the flight
//!    recorder is read), so the lock is taken through
//!    `unwrap_or_else(PoisonError::into_inner)`.
//!
//! Span events carry the owning thread id (a process-local counter,
//! not the OS tid), so per-thread start/end sequences replay as
//! well-formed nesting stacks even when many threads interleave in
//! the shared ring — `tests/obs_props.rs` pins this property.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::{num, obj, s, Json};

use super::now_us;

/// Default ring capacity: enough for a few seconds of busy-engine
/// history (4 phase spans x 2 events per cycle plus request instants)
/// while keeping a full snapshot cheap to clone and dump.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Environment variable gating tracing at construction:
/// `QSPEC_TRACE=0` / `off` / `false` starts tracers disabled.
pub const TRACE_ENV: &str = "QSPEC_TRACE";

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// Process-local thread id: monotone per thread creation, stable
    /// for the thread's lifetime. Cheaper and more readable in dumps
    /// than the OS tid.
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

fn current_tid() -> u64 {
    TID.with(|t| *t)
}

/// What one trace event records.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// a span opened (paired with the `End` carrying the same `span`)
    Start,
    /// a span closed
    End,
    /// a point event with no duration
    Instant,
}

impl EventKind {
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Start => "start",
            EventKind::End => "end",
            EventKind::Instant => "instant",
        }
    }
}

/// One entry in the ring. `name` is always a `&'static str` so the
/// enabled fast path allocates only when a lazy `detail` closure runs.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// monotone per-ring sequence number, assigned at push (first
    /// event is 1). Survives eviction and `clear`, so it doubles as
    /// the cursor for incremental tail reads ([`Tracer::snapshot_since`]
    /// / the v1.7 `{"op":"trace","since":N}` server op).
    pub seq: u64,
    /// microseconds since `obs::init` (process time base)
    pub t_us: u64,
    pub kind: EventKind,
    pub name: &'static str,
    /// span id linking a Start to its End; 0 for instants
    pub span: u64,
    /// process-local id of the emitting thread
    pub tid: u64,
    /// request id the event belongs to, if any
    pub request: Option<u64>,
    /// token count riding along (prompt tokens, committed tokens, ...)
    pub tokens: u64,
    /// optional free-form context (route reason, error text, ...)
    pub detail: Option<String>,
}

impl TraceEvent {
    /// Dump form (flight recorder / `{"op":"dump"}` bodies).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("seq", num(self.seq as f64)),
            ("t_us", num(self.t_us as f64)),
            ("kind", s(self.kind.as_str())),
            ("name", s(self.name)),
            ("span", num(self.span as f64)),
            ("tid", num(self.tid as f64)),
        ];
        if let Some(r) = self.request {
            fields.push(("request", num(r as f64)));
        }
        if self.tokens > 0 {
            fields.push(("tokens", num(self.tokens as f64)));
        }
        if let Some(d) = &self.detail {
            fields.push(("detail", s(d)));
        }
        obj(fields)
    }
}

#[derive(Debug, Default)]
struct RingState {
    ring: VecDeque<TraceEvent>,
    /// events evicted from the full ring since creation/clear
    dropped: u64,
    /// highest sequence number assigned so far (0 = none yet). Never
    /// reset — not even by `clear` — so client cursors stay valid
    /// across ring wipes.
    next_seq: u64,
}

/// The tracing core: an enable flag, a span-id counter, and the
/// bounded ring. Shared as `Arc<Tracer>` between an engine's
/// `BatchCore`, its serving loop, and whoever snapshots the flight
/// recorder.
#[derive(Debug)]
pub struct Tracer {
    enabled: AtomicBool,
    next_span: AtomicU64,
    capacity: usize,
    state: Mutex<RingState>,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new(DEFAULT_RING_CAPACITY)
    }
}

impl Tracer {
    /// An enabled tracer with the given ring capacity (>= 1).
    pub fn new(capacity: usize) -> Self {
        Tracer {
            enabled: AtomicBool::new(true),
            next_span: AtomicU64::new(1),
            capacity: capacity.max(1),
            state: Mutex::new(RingState::default()),
        }
    }

    /// A tracer that starts disabled (`set_enabled(true)` arms it).
    pub fn disabled(capacity: usize) -> Self {
        let t = Self::new(capacity);
        t.enabled.store(false, Ordering::Relaxed);
        t
    }

    /// Default-capacity tracer honoring [`TRACE_ENV`]: enabled unless
    /// the environment says `0` / `off` / `false`.
    pub fn from_env() -> Self {
        let off = std::env::var(TRACE_ENV)
            .map(|v| matches!(v.trim(), "0" | "off" | "false"))
            .unwrap_or(false);
        if off {
            Self::disabled(DEFAULT_RING_CAPACITY)
        } else {
            Self::new(DEFAULT_RING_CAPACITY)
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, RingState> {
        // a panicking span holder must not poison the flight recorder
        self.state.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn push(&self, mut ev: TraceEvent) {
        let mut st = self.lock();
        st.next_seq += 1;
        ev.seq = st.next_seq;
        if st.ring.len() >= self.capacity {
            st.ring.pop_front();
            st.dropped += 1;
        }
        st.ring.push_back(ev);
    }

    /// Point event. No-op (and allocation-free) when disabled.
    pub fn instant(&self, name: &'static str, request: Option<u64>, tokens: u64) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent {
            seq: 0, // assigned in push
            t_us: now_us(),
            kind: EventKind::Instant,
            name,
            span: 0,
            tid: current_tid(),
            request,
            tokens,
            detail: None,
        });
    }

    /// Point event with lazily built detail text: the closure only
    /// runs when tracing is enabled, so callers can format reasons
    /// without paying for them on the disabled path.
    pub fn instant_with(
        &self,
        name: &'static str,
        request: Option<u64>,
        tokens: u64,
        detail: impl FnOnce() -> String,
    ) {
        if !self.enabled() {
            return;
        }
        self.push(TraceEvent {
            seq: 0, // assigned in push
            t_us: now_us(),
            kind: EventKind::Instant,
            name,
            span: 0,
            tid: current_tid(),
            request,
            tokens,
            detail: Some(detail()),
        });
    }

    /// Open a span: emits `Start` now, `End` when the returned guard
    /// drops. A span opened while disabled stays silent even if
    /// tracing is enabled before it closes (no orphan `End`s).
    pub fn scope(self: &Arc<Self>, name: &'static str) -> SpanScope {
        self.scope_req(name, None, 0)
    }

    /// [`Self::scope`] carrying a request id and token count.
    pub fn scope_req(
        self: &Arc<Self>,
        name: &'static str,
        request: Option<u64>,
        tokens: u64,
    ) -> SpanScope {
        if !self.enabled() {
            return SpanScope { tracer: None, name, span: 0, request };
        }
        let span = self.next_span.fetch_add(1, Ordering::Relaxed);
        self.push(TraceEvent {
            seq: 0, // assigned in push
            t_us: now_us(),
            kind: EventKind::Start,
            name,
            span,
            tid: current_tid(),
            request,
            tokens,
            detail: None,
        });
        SpanScope { tracer: Some(self.clone()), name, span, request }
    }

    /// Clone out the ring's current contents, oldest first.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.lock().ring.iter().cloned().collect()
    }

    /// Incremental tail read (v1.7 `{"op":"trace","since":N}`).
    ///
    /// Returns `(events, next_since, dropped)`:
    ///
    /// - `events` — ring contents with `seq > since`, oldest first.
    ///   `since = 0` reads the whole ring (seqs start at 1).
    /// - `next_since` — the cursor to pass on the next call: the
    ///   highest sequence number assigned so far (equals `since`'s
    ///   echo when nothing new happened).
    /// - `dropped` — how many events in `(since, next_since]` were
    ///   already evicted (or cleared) before this read: the gap the
    ///   caller can never recover. 0 means the tail is gapless.
    pub fn snapshot_since(&self, since: u64) -> (Vec<TraceEvent>, u64, u64) {
        let st = self.lock();
        let next_since = st.next_seq;
        let events: Vec<TraceEvent> =
            st.ring.iter().filter(|e| e.seq > since).cloned().collect();
        // oldest seq still unavailable to this cursor: everything up
        // to (ring front - 1), or everything assigned if the ring is
        // empty (cleared / fully evicted).
        let oldest_gone = match st.ring.front() {
            Some(front) => front.seq - 1,
            None => st.next_seq,
        };
        let dropped = oldest_gone.saturating_sub(since.min(next_since));
        (events, next_since, dropped)
    }

    /// Events evicted from the full ring since creation/clear.
    pub fn dropped(&self) -> u64 {
        self.lock().dropped
    }

    pub fn len(&self) -> usize {
        self.lock().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().ring.is_empty()
    }

    /// Empty the ring (and the drop counter).
    pub fn clear(&self) {
        let mut st = self.lock();
        st.ring.clear();
        st.dropped = 0;
    }
}

/// RAII guard closing a span on drop. Owns its `Arc<Tracer>` so it
/// never borrows the engine that opened it — phase code can mutate
/// the `BatchCore` freely while a scope is live.
pub struct SpanScope {
    tracer: Option<Arc<Tracer>>,
    name: &'static str,
    span: u64,
    request: Option<u64>,
}

impl Drop for SpanScope {
    fn drop(&mut self) {
        if let Some(t) = self.tracer.take() {
            t.push(TraceEvent {
                seq: 0, // assigned in push
                t_us: now_us(),
                kind: EventKind::End,
                name: self.name,
                span: self.span,
                tid: current_tid(),
                request: self.request,
                tokens: 0,
                detail: None,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_pair_start_and_end() {
        let t = Arc::new(Tracer::new(64));
        {
            let _outer = t.scope("outer");
            let _inner = t.scope_req("inner", Some(7), 3);
        }
        let evs = t.snapshot();
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].kind, EventKind::Start);
        assert_eq!(evs[0].name, "outer");
        assert_eq!(evs[1].name, "inner");
        assert_eq!(evs[1].request, Some(7));
        // inner closes before outer (drop order)
        assert_eq!(evs[2].kind, EventKind::End);
        assert_eq!(evs[2].span, evs[1].span);
        assert_eq!(evs[3].span, evs[0].span);
        assert_ne!(evs[0].span, evs[1].span);
    }

    #[test]
    fn disabled_tracer_emits_nothing() {
        let t = Arc::new(Tracer::disabled(64));
        assert!(!t.enabled());
        t.instant("ev", None, 0);
        t.instant_with("ev2", Some(1), 2, || unreachable!("lazy detail must not run"));
        {
            let _g = t.scope("quiet");
            // enabling mid-span must not produce an orphan End
            t.set_enabled(true);
        }
        assert!(t.is_empty());
        t.instant("now", None, 0);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let t = Tracer::new(8);
        for _ in 0..100 {
            t.instant("tick", None, 0);
        }
        assert_eq!(t.len(), 8);
        assert_eq!(t.dropped(), 92);
        t.clear();
        assert!(t.is_empty());
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn event_json_shape() {
        let t = Tracer::new(8);
        t.instant_with("route.shed", Some(42), 5, || "pool full".into());
        let j = t.snapshot()[0].to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("route.shed"));
        assert_eq!(j.get("kind").unwrap().as_str(), Some("instant"));
        assert_eq!(j.get("request").unwrap().as_i64(), Some(42));
        assert_eq!(j.get("tokens").unwrap().as_i64(), Some(5));
        assert_eq!(j.get("detail").unwrap().as_str(), Some("pool full"));
        // round-trips through the line protocol's JSON
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn snapshot_since_tails_the_ring_incrementally() {
        let t = Tracer::new(64);
        t.instant("a", None, 0);
        t.instant("b", None, 0);

        // cursor 0 reads everything assigned so far
        let (evs, next, dropped) = t.snapshot_since(0);
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].seq, 1);
        assert_eq!(evs[1].seq, 2);
        assert_eq!(next, 2);
        assert_eq!(dropped, 0);

        // nothing new: empty tail, cursor echoes back
        let (evs, next2, dropped) = t.snapshot_since(next);
        assert!(evs.is_empty());
        assert_eq!(next2, next);
        assert_eq!(dropped, 0);

        // new events appear after the cursor only
        t.instant("c", None, 0);
        let (evs, next3, dropped) = t.snapshot_since(next2);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].name, "c");
        assert_eq!(evs[0].seq, 3);
        assert_eq!(next3, 3);
        assert_eq!(dropped, 0);
    }

    #[test]
    fn snapshot_since_counts_the_evicted_gap() {
        let t = Tracer::new(4);
        for _ in 0..10 {
            t.instant("tick", None, 0);
        }
        // ring holds seqs 7..=10; a cursor at 2 lost 3..=6
        let (evs, next, dropped) = t.snapshot_since(2);
        assert_eq!(evs.len(), 4);
        assert_eq!(evs[0].seq, 7);
        assert_eq!(next, 10);
        assert_eq!(dropped, 4);

        // a caught-up cursor sees no gap despite past evictions
        let (_, _, dropped) = t.snapshot_since(next);
        assert_eq!(dropped, 0);

        // clear wipes the ring but keeps seqs monotone: the stale
        // cursor reports the wiped span as dropped, new events resume
        t.clear();
        t.instant("fresh", None, 0);
        let (evs, next2, dropped) = t.snapshot_since(next);
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].seq, 11);
        assert_eq!(next2, 11);
        assert_eq!(dropped, 0);
        let (_, _, dropped_stale) = t.snapshot_since(2);
        assert_eq!(dropped_stale, 8); // 3..=10 gone
    }

    #[test]
    fn timestamps_are_monotone_within_a_thread() {
        let t = Arc::new(Tracer::new(16));
        let _g = t.scope("a");
        t.instant("b", None, 0);
        let evs = t.snapshot();
        assert!(evs.windows(2).all(|w| w[0].t_us <= w[1].t_us));
    }
}
