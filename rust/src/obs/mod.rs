//! Observability: structured tracing, metrics export, and the crash
//! flight recorder (protocol v1.5).
//!
//! Three pillars, each usable on its own:
//!
//! * [`trace`] — a lightweight span/event API backed by a bounded
//!   ring buffer. The engines open `phase.*` spans around prefill /
//!   draft / verify / commit, the `BatchCore` stamps `request.*`
//!   lifecycle instants (submitted, admitted, done, cancelled, ...),
//!   and the router/transport layers stamp `route.*` / `replica.*`
//!   events — so one request's timeline reconstructs across router
//!   and worker from their rings. Disabled tracing is a single
//!   relaxed atomic load: zero allocation, zero locking.
//! * [`export`] — renders a `stats` frame (per-replica v1.1 shape or
//!   the pooled v1.5 shape, including the sparse `hist` histograms)
//!   as Prometheus text exposition, served from the `{"op":"metrics"}`
//!   wire op and the router's `--metrics-addr` HTTP scrape endpoint.
//! * [`flight`] — snapshots a tracer's ring into a JSON artifact on
//!   replica death, worker panic, or an explicit `{"op":"dump"}`, so
//!   the seconds before a failure are always inspectable.
//!
//! The time base is shared: every event carries microseconds since
//! [`init`] (first use wins), so events from different tracers in one
//! process order correctly.

pub mod export;
pub mod flight;
pub mod trace;

pub use trace::{EventKind, SpanScope, TraceEvent, Tracer};

use std::sync::OnceLock;
use std::time::Instant;

static PROCESS_START: OnceLock<Instant> = OnceLock::new();

/// Pin the process time base. Idempotent; `main` calls it first thing
/// so `uptime_ms` measures the whole process, but any earlier caller
/// of [`now_us`]/[`uptime_ms`] pins it implicitly.
pub fn init() {
    let _ = PROCESS_START.get_or_init(Instant::now);
}

fn start() -> Instant {
    *PROCESS_START.get_or_init(Instant::now)
}

/// Milliseconds since [`init`] — the `uptime_ms` field of every stats
/// frame and flight dump.
pub fn uptime_ms() -> u64 {
    start().elapsed().as_millis().min(u64::MAX as u128) as u64
}

/// Microseconds since [`init`] — the timestamp on every trace event.
pub fn now_us() -> u64 {
    start().elapsed().as_micros().min(u64::MAX as u128) as u64
}

/// The crate version baked into stats frames, `qspec_build_info`, and
/// flight dumps, so every scrape and artifact is attributable to a
/// build.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_base_is_monotone() {
        init();
        let a = now_us();
        let b = now_us();
        assert!(b >= a);
        assert!(uptime_ms() <= now_us() / 1000 + 1);
        assert!(!version().is_empty());
    }
}
