//! Prometheus text exposition over a `stats` frame.
//!
//! The renderer is deliberately *frame-shaped*, not engine-shaped: it
//! takes the JSON `stats` snapshot (a per-replica v1.1 frame or the
//! pooled v1.5 frame — same keys, the pooled one adds lifecycle
//! counters and a `replicas` array) and emits text-format metrics.
//! That keeps one code path for all three serving surfaces — the
//! `{"op":"metrics"}` wire op on a bare engine loop, the same op on
//! the pool router, and the router's `--metrics-addr` HTTP scrape
//! endpoint — and means the exporter can never disagree with what
//! `stats` reports.
//!
//! Conventions: counters get a `_total` suffix, time gauges are
//! converted to seconds, the sparse `hist` field (v1.5 `stats`
//! addition: `[upper_bound, count]` pairs per histogram) renders as
//! cumulative Prometheus histograms with a `+Inf` bucket, and
//! `qspec_build_info` carries version / protocol / engine / sched /
//! route as labels on a constant `1`.

use crate::util::json::Json;

/// `Content-Type` the HTTP scrape endpoint answers with.
pub const PROMETHEUS_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

fn esc(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

fn sample(out: &mut String, name: &str, labels: &[(&str, String)], v: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, val)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{k}=\"{}\"", esc(val)));
        }
        out.push('}');
    }
    out.push_str(&format!(" {v}\n"));
}

fn num_field(stats: &Json, key: &str) -> Option<f64> {
    stats.get(key).and_then(Json::as_f64)
}

/// Emit one top-level numeric field as a counter/gauge, silently
/// skipping fields the frame doesn't carry (a bare engine frame has
/// no lifecycle counters; `null` rates are simply absent).
fn metric(out: &mut String, stats: &Json, key: &str, name: &str, help: &str, kind: &str) {
    if let Some(v) = num_field(stats, key) {
        header(out, name, help, kind);
        sample(out, name, &[], v);
    }
}

/// Scaled variant (ms -> s conversions).
fn metric_scaled(
    out: &mut String,
    stats: &Json,
    key: &str,
    name: &str,
    help: &str,
    kind: &str,
    scale: f64,
) {
    if let Some(v) = num_field(stats, key) {
        header(out, name, help, kind);
        sample(out, name, &[], v * scale);
    }
}

/// Render one sparse `[upper, count]` histogram as cumulative
/// Prometheus buckets (`scale` converts the stored upper bounds, e.g.
/// ns -> s). `_sum` is approximated from the bucket upper bounds —
/// exact sums are not tracked, and the approximation errs high by at
/// most one bucket width (~6%).
fn histogram(out: &mut String, name: &str, help: &str, pairs: &[Json], scale: f64) {
    header(out, name, help, "histogram");
    let mut cum = 0.0;
    let mut sum = 0.0;
    for p in pairs {
        let Some([le, count]) = p.as_arr().and_then(|a| {
            Some([a.first()?.as_f64()?, a.get(1)?.as_f64()?])
        }) else {
            continue;
        };
        cum += count;
        sum += le * scale * count;
        sample(out, &format!("{name}_bucket"), &[("le", format!("{}", le * scale))], cum);
    }
    sample(out, &format!("{name}_bucket"), &[("le", "+Inf".to_string())], cum);
    sample(out, &format!("{name}_sum"), &[], sum);
    sample(out, &format!("{name}_count"), &[], cum);
}

/// Render a `stats` frame as Prometheus text. Works on any frame
/// shape the server produces; unknown/missing fields are skipped, so
/// v1.4-era cached snapshots degrade gracefully.
pub fn prometheus(stats: &Json) -> String {
    let mut out = String::new();

    // build identity as labels on a constant: this is how scrapes and
    // dashboards attribute a time series to a build/config
    let mut labels: Vec<(&str, String)> = Vec::new();
    for key in ["version", "protocol", "engine", "sched", "route"] {
        if let Some(v) = stats.get(key).and_then(Json::as_str) {
            labels.push((key, v.to_string()));
        }
    }
    header(&mut out, "qspec_build_info", "build/config identity (constant 1)", "gauge");
    sample(&mut out, "qspec_build_info", &labels, 1.0);

    metric_scaled(
        &mut out,
        stats,
        "uptime_ms",
        "qspec_uptime_seconds",
        "seconds since process start",
        "gauge",
        1e-3,
    );

    // cumulative counters
    for (key, name, help) in [
        ("requests_done", "qspec_requests_done_total", "requests finished"),
        ("cancelled", "qspec_cancelled_total", "requests cancelled mid-flight"),
        ("shed", "qspec_shed_total", "admissions rejected by the SLO"),
        ("deadline_expired", "qspec_deadline_expired_total", "requests expired in queue"),
        ("tokens_out", "qspec_tokens_out_total", "tokens generated"),
        ("drafted", "qspec_drafted_total", "draft tokens proposed"),
        ("accepted", "qspec_accepted_total", "draft tokens accepted"),
        ("prefix_queries", "qspec_prefix_queries_total", "prefix-cache lookups"),
        ("prefix_hit_tokens", "qspec_prefix_hit_tokens_total", "prompt tokens served from cache"),
        // pool lifecycle (router frames only)
        ("restarts", "qspec_restarts_total", "replicas replaced after death"),
        ("stolen", "qspec_stolen_total", "queued requests re-admitted from dead replicas"),
        ("lost_streams", "qspec_lost_streams_total", "in-flight streams cut by replica death"),
        ("scale_ups", "qspec_scale_ups_total", "vacant slots filled by the autoscaler"),
        ("scale_downs", "qspec_scale_downs_total", "replicas retired to vacancy"),
    ] {
        metric(&mut out, stats, key, name, help, "counter");
    }

    // live gauges
    metric(&mut out, stats, "queue_depth", "qspec_queue_depth", "requests queued", "gauge");
    metric(&mut out, stats, "active", "qspec_active_requests", "requests generating", "gauge");
    metric(&mut out, stats, "slots", "qspec_slots", "generation slot capacity", "gauge");
    metric(
        &mut out,
        stats,
        "acceptance_rate",
        "qspec_acceptance_rate",
        "accepted/drafted ratio",
        "gauge",
    );
    metric(
        &mut out,
        stats,
        "prefix_hit_rate",
        "qspec_prefix_hit_tokens_per_query",
        "mean cached prompt tokens per lookup",
        "gauge",
    );
    metric(
        &mut out,
        stats,
        "wall_tok_s",
        "qspec_wall_tokens_per_second",
        "wall-clock generation throughput",
        "gauge",
    );
    metric(
        &mut out,
        stats,
        "virt_tok_s",
        "qspec_virt_tokens_per_second",
        "cost-model generation throughput",
        "gauge",
    );
    for (key, name, help) in [
        ("oldest_queued_ms", "qspec_oldest_queued_seconds", "age of the oldest queued request"),
        ("queue_p50_ms", "qspec_queue_wait_p50_seconds", "median queue wait"),
        ("queue_p99_ms", "qspec_queue_wait_p99_seconds", "p99 queue wait"),
        ("latency_p50_ms", "qspec_request_latency_p50_seconds", "median request latency"),
        ("latency_p99_ms", "qspec_request_latency_p99_seconds", "p99 request latency"),
    ] {
        metric_scaled(&mut out, stats, key, name, help, "gauge", 1e-3);
    }

    if let Some(depths) = stats.get("queue_depth_by_priority").and_then(Json::as_arr) {
        header(
            &mut out,
            "qspec_queue_depth_class",
            "requests queued per priority class",
            "gauge",
        );
        for (c, d) in depths.iter().enumerate() {
            if let Some(v) = d.as_f64() {
                sample(&mut out, "qspec_queue_depth_class", &[("class", c.to_string())], v);
            }
        }
    }

    // v1.5 histograms: sparse [upper, count] pairs from the frame
    if let Some(h) = stats.get("hist") {
        if let Some(p) = h.get("req_latency_ns").and_then(Json::as_arr) {
            histogram(
                &mut out,
                "qspec_request_latency_seconds",
                "end-to-end request latency",
                p,
                1e-9,
            );
        }
        if let Some(p) = h.get("queue_wait_ns").and_then(Json::as_arr) {
            histogram(
                &mut out,
                "qspec_queue_wait_seconds",
                "submit-to-admission queue wait",
                p,
                1e-9,
            );
        }
        if let Some(p) = h.get("accept_len").and_then(Json::as_arr) {
            histogram(
                &mut out,
                "qspec_accept_len",
                "accepted drafts per verify cycle",
                p,
                1.0,
            );
        }
    }

    // per-replica breakdown (pooled frames)
    if let Some(reps) = stats.get("replicas").and_then(Json::as_arr) {
        let per_replica: [(&str, &str, &str, &str); 6] = [
            ("queue_depth", "qspec_replica_queue_depth", "requests queued", "gauge"),
            ("active", "qspec_replica_active_requests", "requests generating", "gauge"),
            ("requests_done", "qspec_replica_requests_done_total", "requests finished", "counter"),
            ("tokens_out", "qspec_replica_tokens_out_total", "tokens generated", "counter"),
            ("acceptance_rate", "qspec_replica_acceptance_rate", "accepted/drafted", "gauge"),
            ("wall_tok_s", "qspec_replica_wall_tokens_per_second", "throughput", "gauge"),
        ];
        for (key, name, help, kind) in per_replica {
            let mut wrote_header = false;
            for r in reps {
                let Some(k) = r.get("replica").and_then(Json::as_f64) else { continue };
                let Some(v) = r.get(key).and_then(Json::as_f64) else { continue };
                if !wrote_header {
                    header(&mut out, name, help, kind);
                    wrote_header = true;
                }
                let mut labels = vec![("replica", format!("{k}"))];
                if let Some(e) = r.get("engine").and_then(Json::as_str) {
                    labels.push(("engine", e.to_string()));
                }
                sample(&mut out, name, &labels, v);
            }
        }
        header(&mut out, "qspec_replica_draining", "1 while draining", "gauge");
        for r in reps {
            let Some(k) = r.get("replica").and_then(Json::as_f64) else { continue };
            let draining = matches!(r.get("draining"), Some(Json::Bool(true)));
            sample(
                &mut out,
                "qspec_replica_draining",
                &[("replica", format!("{k}"))],
                if draining { 1.0 } else { 0.0 },
            );
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame() -> Json {
        Json::parse(
            r#"{"engine":"mock","sched":"fcfs","route":"round_robin",
                "version":"0.3.0","protocol":"v1.5","uptime_ms":2500,
                "queue_depth":2,"queue_depth_by_priority":[1,1,0,0],
                "oldest_queued_ms":3.5,"active":1,"slots":8,
                "requests_done":7,"cancelled":1,"shed":0,
                "deadline_expired":0,"tokens_out":40,"drafted":10,
                "accepted":8,"acceptance_rate":0.8,"prefix_queries":4,
                "prefix_hit_tokens":32,"prefix_hit_rate":8.0,
                "wall_tok_s":100.5,"virt_tok_s":900.0,"queue_p50_ms":1.0,
                "queue_p99_ms":2.0,"latency_p50_ms":5.0,"latency_p99_ms":9.0,
                "restarts":1,"stolen":2,"lost_streams":0,"scale_ups":0,
                "scale_downs":0,
                "hist":{"req_latency_ns":[[1000000,3],[8000000,4]],
                        "queue_wait_ns":[[500000,7]],
                        "accept_len":[[1,2],[3,5]]},
                "replicas":[{"replica":0,"engine":"mock","queue_depth":2,
                             "active":1,"requests_done":7,"tokens_out":40,
                             "acceptance_rate":0.8,"wall_tok_s":100.5,
                             "draining":false}]}"#,
        )
        .unwrap()
    }

    #[test]
    fn build_info_carries_identity_labels() {
        let text = prometheus(&frame());
        assert!(text.contains(
            "qspec_build_info{version=\"0.3.0\",protocol=\"v1.5\",engine=\"mock\",\
             sched=\"fcfs\",route=\"round_robin\"} 1"
        ));
        assert!(text.contains("qspec_uptime_seconds 2.5"));
    }

    #[test]
    fn counters_and_gauges_have_help_and_type() {
        let text = prometheus(&frame());
        for name in [
            "qspec_requests_done_total",
            "qspec_tokens_out_total",
            "qspec_restarts_total",
            "qspec_queue_depth",
            "qspec_acceptance_rate",
            "qspec_wall_tokens_per_second",
        ] {
            assert!(text.contains(&format!("# HELP {name} ")), "missing HELP for {name}");
            assert!(text.contains(&format!("# TYPE {name} ")), "missing TYPE for {name}");
            assert!(text.contains(&format!("{name} ")), "missing sample for {name}");
        }
        assert!(text.contains("qspec_requests_done_total 7"));
        assert!(text.contains("qspec_queue_depth_class{class=\"1\"} 1"));
        assert!(text.contains("qspec_queue_wait_p99_seconds 0.002"));
    }

    #[test]
    fn histograms_are_cumulative_with_inf() {
        let text = prometheus(&frame());
        assert!(text.contains("# TYPE qspec_request_latency_seconds histogram"));
        assert!(text.contains("qspec_request_latency_seconds_bucket{le=\"0.001\"} 3"));
        assert!(text.contains("qspec_request_latency_seconds_bucket{le=\"0.008\"} 7"));
        assert!(text.contains("qspec_request_latency_seconds_bucket{le=\"+Inf\"} 7"));
        assert!(text.contains("qspec_request_latency_seconds_count 7"));
        assert!(text.contains("qspec_accept_len_bucket{le=\"3\"} 7"));
        assert!(text.contains("qspec_accept_len_count 7"));
    }

    #[test]
    fn per_replica_series_are_labeled() {
        let text = prometheus(&frame());
        assert!(text
            .contains("qspec_replica_queue_depth{replica=\"0\",engine=\"mock\"} 2"));
        assert!(text.contains("qspec_replica_draining{replica=\"0\"} 0"));
    }

    #[test]
    fn sparse_frames_render_without_optional_fields() {
        // a bare engine frame: no route, no lifecycle, no hist, null
        // acceptance — nothing may panic or emit garbage
        let j = Json::parse(
            r#"{"engine":"qspec","sched":"fcfs","queue_depth":0,"active":0,
                "slots":8,"requests_done":0,"acceptance_rate":null}"#,
        )
        .unwrap();
        let text = prometheus(&j);
        assert!(text.contains("qspec_build_info{engine=\"qspec\",sched=\"fcfs\"} 1"));
        assert!(text.contains("qspec_queue_depth 0"));
        assert!(!text.contains("qspec_restarts_total"));
        assert!(!text.contains("qspec_acceptance_rate"), "null renders as absent");
        // every non-comment line is "name{...} value"
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, val) = line.rsplit_once(' ').expect("metric line");
            assert!(!name.is_empty());
            assert!(val.parse::<f64>().is_ok() || val == "+Inf", "bad value {val}");
        }
    }

    #[test]
    fn label_values_are_escaped() {
        assert_eq!(esc("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }
}
