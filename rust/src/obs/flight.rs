//! Crash flight recorder: snapshot a tracer's ring into a JSON
//! artifact when something dies.
//!
//! The tracer already keeps the last N events in a bounded ring; this
//! module is the *exit path* — it turns that ring into a single-line
//! JSON dump and writes it to a `flight-*.json` file. Three triggers:
//!
//! * a worker's engine loop panics (`transport::serve_worker` catches
//!   the unwind and dumps the engine's own ring);
//! * the router detects a replica death (`pool::note_dead` dumps the
//!   router's ring, which holds the routing/heartbeat timeline for
//!   the lost replica);
//! * an operator sends `{"op":"dump"}` for a live snapshot.
//!
//! Dumps are one JSON object per file so `jq` / `Json::parse` read
//! them directly; the filename embeds who dumped and when:
//! `flight-<replica|router>-<uptime_ms>-<seq>.json`.
//!
//! Retention: every write rotates the directory down to at most
//! `$QSPEC_FLIGHT_KEEP` dumps (default 32, `0` = unbounded), deleting
//! oldest-first by mtime; the dump just written is never a deletion
//! candidate, so the artifact for the incident that triggered the
//! rotation always survives it.

use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::json::{arr, num, obj, s, Json};

use super::trace::TraceEvent;

/// Environment variable overriding where serving paths write flight
/// dumps; default `flight-dumps/` under the working directory.
/// Library/bench/test paths never write dumps unless given a dir
/// explicitly, so nothing pollutes the cwd outside `serve`.
pub const FLIGHT_DIR_ENV: &str = "QSPEC_FLIGHT_DIR";

/// The dump directory for serving paths: `$QSPEC_FLIGHT_DIR` or
/// `flight-dumps`.
pub fn dir_from_env() -> PathBuf {
    std::env::var(FLIGHT_DIR_ENV)
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("flight-dumps"))
}

/// Environment variable bounding how many `flight-*.json` files are
/// kept in the dump directory (oldest deleted first). Default
/// [`FLIGHT_KEEP_DEFAULT`]; `0` disables rotation (unbounded, the
/// pre-retention behavior).
pub const FLIGHT_KEEP_ENV: &str = "QSPEC_FLIGHT_KEEP";

/// Default retention: enough to cover a burst of replica deaths plus
/// operator dumps without growing without bound on a long-lived pool.
pub const FLIGHT_KEEP_DEFAULT: usize = 32;

/// The retention cap: `$QSPEC_FLIGHT_KEEP` or [`FLIGHT_KEEP_DEFAULT`];
/// unparseable values fall back to the default.
pub fn keep_from_env() -> usize {
    std::env::var(FLIGHT_KEEP_ENV)
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(FLIGHT_KEEP_DEFAULT)
}

/// Delete the oldest `flight-*.json` files in `dir` until at most
/// `keep` remain. `just_written` is never deleted, whatever the
/// clock says — the dump that triggered rotation must survive it.
/// Ordered by modification time (filename as tie-break, which embeds
/// uptime+seq and so orders same-mtime dumps correctly). Best-effort:
/// I/O errors skip the file rather than propagate — rotation runs on
/// death paths and must never make things worse.
fn rotate(dir: &Path, keep: usize, just_written: &Path) {
    if keep == 0 {
        return; // rotation disabled
    }
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return,
    };
    let mut dumps: Vec<(std::time::SystemTime, PathBuf)> = Vec::new();
    for entry in entries.flatten() {
        let path = entry.path();
        let is_dump = path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.starts_with("flight-") && n.ends_with(".json"))
            .unwrap_or(false);
        if !is_dump || path == just_written {
            continue;
        }
        let mtime = entry
            .metadata()
            .and_then(|m| m.modified())
            .unwrap_or(std::time::SystemTime::UNIX_EPOCH);
        dumps.push((mtime, path));
    }
    // `just_written` is excluded above, so it occupies one of the
    // `keep` slots unconditionally
    let budget = keep.saturating_sub(1);
    if dumps.len() <= budget {
        return;
    }
    dumps.sort_by(|a, b| a.0.cmp(&b.0).then_with(|| a.1.cmp(&b.1)));
    for (_, path) in dumps.drain(..dumps.len() - budget) {
        if let Err(e) = fs::remove_file(&path) {
            log::warn!("flight recorder: rotation failed to remove {}: {e}", path.display());
        }
    }
}

/// Monotone per-process dump counter — keeps filenames unique even
/// when two dumps land in the same millisecond (e.g. a panic dump and
/// the router-side death dump for the same incident).
static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Build the dump object for a ring snapshot. `replica` is `None` for
/// router-side dumps; `dropped` is how many older events the ring
/// evicted before the snapshot (so readers know the window is
/// truncated, not complete).
pub fn dump_json(
    reason: &str,
    replica: Option<usize>,
    engine: &str,
    events: &[TraceEvent],
    dropped: u64,
) -> Json {
    obj(vec![
        ("reason", s(reason)),
        (
            "replica",
            match replica {
                Some(k) => num(k as f64),
                None => Json::Null,
            },
        ),
        ("engine", s(engine)),
        ("version", s(super::version())),
        ("protocol", s(crate::server::PROTOCOL_VERSION)),
        ("uptime_ms", num(super::uptime_ms() as f64)),
        ("dropped", num(dropped as f64)),
        ("n_events", num(events.len() as f64)),
        ("events", arr(events.iter().map(TraceEvent::to_json).collect())),
    ])
}

/// Write a dump object to `dir` (created if needed). Returns the path
/// written. Failures are returned, not panicked on — the flight
/// recorder runs on death paths and must never make things worse.
pub fn write_dump(dir: &Path, dump: &Json) -> io::Result<PathBuf> {
    fs::create_dir_all(dir)?;
    let who = match dump.get("replica").and_then(Json::as_usize) {
        Some(k) => format!("{k}"),
        None => "router".to_string(),
    };
    let seq = DUMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("flight-{who}-{}-{seq}.json", super::uptime_ms()));
    let mut f = fs::File::create(&path)?;
    f.write_all(dump.to_string().as_bytes())?;
    f.write_all(b"\n")?;
    rotate(dir, keep_from_env(), &path);
    Ok(path)
}

/// Convenience: snapshot `tracer` and write a dump, logging (not
/// propagating) any I/O error. Used from the death paths where the
/// caller has nothing useful to do with a failure.
pub fn record(
    dir: &Path,
    reason: &str,
    replica: Option<usize>,
    engine: &str,
    tracer: &super::Tracer,
) -> Option<PathBuf> {
    let dump = dump_json(reason, replica, engine, &tracer.snapshot(), tracer.dropped());
    match write_dump(dir, &dump) {
        Ok(p) => {
            log::info!("flight recorder: wrote {} ({reason})", p.display());
            Some(p)
        }
        Err(e) => {
            log::warn!("flight recorder: dump failed: {e}");
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Tracer;
    use std::sync::Arc;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("qspec-flight-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn dump_json_shape() {
        let t = Arc::new(Tracer::new(16));
        t.instant("request.submitted", Some(7), 3);
        let span = t.scope("phase.draft");
        drop(span);
        let d = dump_json("test", Some(2), "mock", &t.snapshot(), t.dropped());
        assert_eq!(d.get("reason").and_then(Json::as_str), Some("test"));
        assert_eq!(d.get("replica").and_then(Json::as_usize), Some(2));
        assert_eq!(d.get("n_events").and_then(Json::as_usize), Some(3));
        assert_eq!(d.get("dropped").and_then(Json::as_usize), Some(0));
        let evs = d.get("events").and_then(Json::as_arr).expect("events");
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("name").and_then(Json::as_str), Some("request.submitted"));
        // round-trips through the wire encoding
        let rt = Json::parse(&d.to_string()).expect("parse");
        assert_eq!(rt.get("engine").and_then(Json::as_str), Some("mock"));
    }

    #[test]
    fn router_dump_has_null_replica() {
        let d = dump_json("replica_lost", None, "pool", &[], 0);
        assert!(matches!(d.get("replica"), Some(Json::Null)));
    }

    #[test]
    fn write_dump_creates_unique_parseable_files() {
        let dir = tmpdir("write");
        let t = Arc::new(Tracer::new(8));
        t.instant("route.assign", Some(1), 0);
        let d = dump_json("panic: boom", Some(0), "mock", &t.snapshot(), 0);
        let p1 = write_dump(&dir, &d).expect("write 1");
        let p2 = write_dump(&dir, &d).expect("write 2");
        assert_ne!(p1, p2, "seq counter keeps filenames unique");
        let text = fs::read_to_string(&p1).expect("read");
        let back = Json::parse(text.trim()).expect("parse dump file");
        assert_eq!(back.get("reason").and_then(Json::as_str), Some("panic: boom"));
        assert!(p1.file_name().unwrap().to_str().unwrap().starts_with("flight-0-"));
        let _ = fs::remove_dir_all(&dir);
    }

    fn dump_names(dir: &Path) -> Vec<String> {
        let mut names: Vec<String> = fs::read_dir(dir)
            .map(|rd| {
                rd.flatten()
                    .filter_map(|e| e.file_name().to_str().map(String::from))
                    .filter(|n| n.starts_with("flight-") && n.ends_with(".json"))
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    #[test]
    fn rotation_caps_count_oldest_first_and_spares_newest() {
        let dir = tmpdir("rotate");
        let d = dump_json("test", Some(0), "mock", &[], 0);
        let mut paths = Vec::new();
        for _ in 0..6 {
            paths.push(write_dump(&dir, &d).expect("write"));
        }
        assert_eq!(dump_names(&dir).len(), 6, "default keep (32) must not rotate 6 dumps");
        let newest = paths.last().unwrap().clone();
        rotate(&dir, 3, &newest);
        let left = dump_names(&dir);
        assert_eq!(left.len(), 3, "rotation caps the directory at keep");
        assert!(
            left.contains(&newest.file_name().unwrap().to_str().unwrap().to_string()),
            "rotation must never delete the newest dump"
        );
        // oldest-first: the first writes are the ones gone
        for gone in &paths[..3] {
            assert!(!gone.exists(), "{} should have been rotated out", gone.display());
        }
        // keep=1 keeps exactly the protected newest dump
        rotate(&dir, 1, &newest);
        assert_eq!(dump_names(&dir).len(), 1);
        assert!(newest.exists());
        // keep=0 disables rotation entirely
        let extra = write_dump(&dir, &d).expect("write");
        rotate(&dir, 0, &extra);
        assert_eq!(dump_names(&dir).len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn keep_from_env_defaults_sanely() {
        // do not mutate the process env here (tests run concurrently);
        // just pin the default constant the env path falls back to
        assert_eq!(FLIGHT_KEEP_DEFAULT, 32);
        if std::env::var(FLIGHT_KEEP_ENV).is_err() {
            assert_eq!(keep_from_env(), FLIGHT_KEEP_DEFAULT);
        }
    }

    #[test]
    fn record_snapshots_tracer() {
        let dir = tmpdir("record");
        let t = Tracer::new(8);
        t.instant("replica.lost", None, 0);
        let p = record(&dir, "replica_lost", None, "pool", &t).expect("dump path");
        let back = Json::parse(fs::read_to_string(&p).unwrap().trim()).unwrap();
        assert_eq!(back.get("n_events").and_then(Json::as_usize), Some(1));
        assert!(p.file_name().unwrap().to_str().unwrap().starts_with("flight-router-"));
        let _ = fs::remove_dir_all(&dir);
    }
}
