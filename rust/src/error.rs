//! Crate-wide error type.

use thiserror::Error;

/// All errors surfaced by the qspec library.
#[derive(Error, Debug)]
pub enum QspecError {
    /// PJRT / XLA runtime failures (compile, execute, transfer).
    #[error("xla: {0}")]
    Xla(String),

    /// Artifact loading problems (missing files, bad manifest, QTNS).
    #[error("artifact: {0}")]
    Artifact(String),

    /// JSON parse errors from the hand-rolled parser.
    #[error("json: {0} at byte {1}")]
    Json(String, usize),

    /// Scheduler invariant violations (bugs, not user errors).
    #[error("scheduler invariant: {0}")]
    Scheduler(String),

    /// Simulated out-of-memory under the cost-model device budget
    /// (Table 5/7 reproduce EAGLE's OOM at batch 16 through this).
    #[error("device OOM (simulated): {0}")]
    Oom(String),

    /// Configuration / CLI errors.
    #[error("config: {0}")]
    Config(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for QspecError {
    fn from(e: xla::Error) -> Self {
        QspecError::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, QspecError>;
