//! Hand-rolled CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `qspec <subcommand> [--key value]... [--flag]...`

use std::collections::BTreeMap;

use crate::error::{QspecError, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    /// last-wins view of the options (the common single-value case).
    pub options: BTreeMap<String, String>,
    /// every `--key value` occurrence in order — repeatable options
    /// (e.g. one `--engine` per pool replica) read this via
    /// [`Args::get_all`].
    pub occurrences: Vec<(String, String)>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut it = raw.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        let mut options = BTreeMap::new();
        let mut occurrences = Vec::new();
        let mut flags = Vec::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    options.insert(k.to_string(), v.to_string());
                    occurrences.push((k.to_string(), v.to_string()));
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    options.insert(key.to_string(), v.clone());
                    occurrences.push((key.to_string(), v));
                } else {
                    flags.push(key.to_string());
                }
            } else {
                return Err(QspecError::Config(format!("unexpected positional arg {a}")));
            }
        }
        Ok(Args { subcommand, options, occurrences, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// Every value passed for a repeatable option, in command-line
    /// order (empty when the option never appeared).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| QspecError::Config(format!("--{key} must be an integer"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| QspecError::Config(format!("--{key} must be a number"))),
        }
    }

    pub fn has_flag(&self, f: &str) -> bool {
        self.flags.iter().any(|x| x == f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parse("serve --size m --batch 16 --verbose");
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.get("size"), Some("m"));
        assert_eq!(a.get_usize("batch", 8).unwrap(), 16);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("bench --gamma=5");
        assert_eq!(a.get("gamma"), Some("5"));
    }

    #[test]
    fn repeated_options_keep_every_occurrence() {
        let a = parse("serve --engine qspec --engine hierspec --engine=w4a16");
        assert_eq!(a.get("engine"), Some("w4a16"), "map view stays last-wins");
        assert_eq!(a.get_all("engine"), vec!["qspec", "hierspec", "w4a16"]);
        assert!(a.get_all("sched").is_empty());
    }

    #[test]
    fn trailing_flag() {
        let a = parse("eval --quick");
        assert!(a.has_flag("quick"));
    }

    #[test]
    fn bad_int_rejected() {
        let a = parse("serve --batch x");
        assert!(a.get_usize("batch", 8).is_err());
    }

    #[test]
    fn float_option() {
        let a = parse("generate --temperature 0.7");
        assert_eq!(a.get_f64("temperature", 0.0).unwrap(), 0.7);
        assert_eq!(a.get_f64("seedless", 1.5).unwrap(), 1.5);
        assert!(parse("generate --temperature warm").get_f64("temperature", 0.0).is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(vec!["serve".into(), "oops".into()]).is_err());
    }
}
