//! L20 roofline cost model — the virtual clock (DESIGN.md §3).
//!
//! The paper's throughput numbers come from NVIDIA L20 GPUs running int4
//! kernels; this substrate executes the same numerics on CPU PJRT. To
//! report paper-comparable *ratios*, every executed call also advances a
//! virtual clock by the time the equivalent kernel would take on an L20
//! against the paper-twin model (Llama-3.2-3B / 2-7B / 3-8B / 2-13B).
//!
//! Decode is modeled memory-bound (weight + KV traffic / HBM bandwidth),
//! prefill/verify compute-bound (FLOPs / effective peak), matching the
//! paper's Sec. 3.2 cost analysis. W4A16 pays a dequantization penalty
//! (expressed as extra effective weight traffic) which is why FP16 can
//! outrun AWQ inside Atom's serving stack (paper Appendix A.6 / Fig. 7).

pub mod l20;
pub mod twins;

use crate::model::Mode;
use twins::Twin;

/// Virtual device clock + memory accounting for one engine run.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub twin: Twin,
    /// accumulated virtual nanoseconds
    pub virtual_ns: u128,
    /// device memory budget (bytes) for OOM simulation
    pub mem_budget: usize,
}

/// Which kernel family a call belongss to (affects peak + traffic).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// T=1 steps (decode, each draft step): memory-bound.
    Decode,
    /// multi-token passes (prefill, verify): compute-leaning.
    Chunk,
}

impl CostModel {
    pub fn new(twin: Twin) -> Self {
        CostModel { twin, virtual_ns: 0, mem_budget: l20::HBM_BYTES }
    }

    /// Virtual cost of one forward call.
    ///
    /// * `mode` — quantization configuration executed
    /// * `batch` — sequences in the batch
    /// * `tokens` — tokens processed per sequence (1 for decode, gamma+1
    ///   for verify, P for prefill)
    /// * `ctx` — mean context length attended
    pub fn call_ns(&self, mode: Mode, phase: Phase, batch: usize, tokens: usize, ctx: usize) -> u128 {
        Self::ns_for(&self.twin, mode, phase, batch, tokens, ctx)
    }

    /// Like [`CostModel::call_ns`], but with the KV cache read at an
    /// explicit bit width instead of the mode-implied one — the
    /// HierSpec draft phase attends over the `kv_bits` shadow tier
    /// while computing at verify (W4A16) weight precision, which is
    /// exactly the bandwidth saving this prices.
    pub fn call_ns_kv_bits(
        &self,
        mode: Mode,
        phase: Phase,
        batch: usize,
        tokens: usize,
        ctx: usize,
        kv_bits: u8,
    ) -> u128 {
        Self::ns_inner(
            &self.twin,
            mode,
            phase,
            batch,
            tokens,
            ctx,
            self.twin.kv_bytes_per_token_bits(kv_bits),
        )
    }

    /// Same, for an arbitrary twin (e.g. a draft model on the same device).
    pub fn ns_for(twin: &Twin, mode: Mode, phase: Phase, batch: usize, tokens: usize, ctx: usize) -> u128 {
        Self::ns_inner(twin, mode, phase, batch, tokens, ctx, twin.kv_bytes_per_token(mode))
    }

    fn ns_inner(
        twin: &Twin,
        mode: Mode,
        phase: Phase,
        batch: usize,
        tokens: usize,
        ctx: usize,
        kv_bytes_per_token: usize,
    ) -> u128 {
        let p = twin.n_params as f64;
        let weight_traffic = match mode {
            // fp16 weights
            Mode::W16A16 => 2.0 * p,
            // int4 weights but a dequant pass per matmul: the effective
            // traffic+compute cost is higher than fp16 in Atom's stack
            // (calibrated to paper Table 6 ratios: W16A16/W4A16 ~ 1.2).
            Mode::W4A16 => 2.4 * p,
            // int4 weights consumed natively by int4 tensor cores, plus
            // runtime activation-quant + group-scale epilogue overheads
            // (calibrated to paper Table 6: W4A4/W4A16 ~ 1.8-2.3x)
            Mode::W4A4 => 1.2 * p,
        };
        let kv_traffic = (batch * ctx * kv_bytes_per_token) as f64 * tokens as f64;
        let mem_ns = (weight_traffic + kv_traffic) / l20::HBM_BW_BYTES_PER_NS;

        let flops = 2.0 * p * (batch * tokens) as f64;
        let peak = match mode {
            Mode::W16A16 => l20::FP16_FLOPS_PER_NS * l20::MFU,
            Mode::W4A16 => l20::FP16_FLOPS_PER_NS * l20::MFU * 0.8, // dequant in-loop
            Mode::W4A4 => l20::INT4_OPS_PER_NS * l20::MFU,
        };
        let comp_ns = flops / peak;

        let roof = match phase {
            Phase::Decode => mem_ns.max(comp_ns),
            Phase::Chunk => comp_ns.max(mem_ns * 0.5), // chunked reuse of weights
        };
        (roof + l20::LAUNCH_OVERHEAD_NS * twin.n_layers as f64) as u128
    }

    /// Advance the clock for an executed call.
    pub fn charge(&mut self, mode: Mode, phase: Phase, batch: usize, tokens: usize, ctx: usize) -> u128 {
        let ns = self.call_ns(mode, phase, batch, tokens, ctx);
        self.virtual_ns += ns;
        ns
    }

    /// Advance the clock for a call whose KV traffic runs at an
    /// explicit bit width (the HierSpec quantized-shadow draft).
    pub fn charge_kv_bits(
        &mut self,
        mode: Mode,
        phase: Phase,
        batch: usize,
        tokens: usize,
        ctx: usize,
        kv_bits: u8,
    ) -> u128 {
        let ns = self.call_ns_kv_bits(mode, phase, batch, tokens, ctx, kv_bits);
        self.virtual_ns += ns;
        ns
    }

    /// Weight bytes resident on the virtual device.
    pub fn weight_bytes(&self, mode: Mode) -> usize {
        match mode {
            Mode::W16A16 => 2 * self.twin.n_params,
            // int4 packed + group scales
            _ => self.twin.n_params / 2 + self.twin.n_params / 64,
        }
    }

    /// KV bytes for `batch` sequences of length `ctx`.
    pub fn kv_bytes(&self, mode: Mode, batch: usize, ctx: usize) -> usize {
        batch * ctx * self.twin.kv_bytes_per_token(mode)
    }

    /// KV bytes at an explicit storage width (the quantized shadow
    /// tier's residency for the OOM simulation).
    pub fn kv_bytes_bits(&self, bits: u8, batch: usize, ctx: usize) -> usize {
        batch * ctx * self.twin.kv_bytes_per_token_bits(bits)
    }

    /// Admission check: would this engine configuration fit in device
    /// memory? Returns Err(QspecError::Oom) when it would not — this is
    /// how Table 5/7's "OOM" rows reproduce.
    pub fn check_memory(
        &self,
        resident: usize,
        label: &str,
    ) -> crate::error::Result<()> {
        if resident > self.mem_budget {
            return Err(crate::error::QspecError::Oom(format!(
                "{label}: {} GiB > {} GiB budget",
                resident >> 30,
                self.mem_budget >> 30
            )));
        }
        Ok(())
    }

    pub fn virtual_seconds(&self) -> f64 {
        self.virtual_ns as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use twins::Twin;

    fn cm() -> CostModel {
        CostModel::new(Twin::lookup("llama2-7b"))
    }

    #[test]
    fn decode_mode_ordering_matches_paper() {
        // paper Table 6: throughput W4A4 > W16A16 > W4A16 at fixed batch
        let c = cm();
        let t = |m| c.call_ns(m, Phase::Decode, 16, 1, 512);
        assert!(t(Mode::W4A4) < t(Mode::W16A16), "w4a4 must be fastest");
        assert!(t(Mode::W16A16) < t(Mode::W4A16), "fp16 beats awq in Atom's stack");
    }

    #[test]
    fn w4a4_vs_w4a16_decode_ratio_near_paper() {
        // paper Table 6 (7B): W4A4/W4A16 throughput ratio ~ 1.9-2.3x
        let c = cm();
        let r = c.call_ns(Mode::W4A16, Phase::Decode, 16, 1, 512) as f64
            / c.call_ns(Mode::W4A4, Phase::Decode, 16, 1, 512) as f64;
        assert!(r > 1.5 && r < 3.5, "ratio {r}");
    }

    #[test]
    fn verify_cheaper_than_gamma_decodes() {
        // parallel verification of gamma+1 tokens must cost well under
        // gamma+1 sequential decode steps (the speculative-decoding win)
        let c = cm();
        let verify = c.call_ns(Mode::W4A16, Phase::Chunk, 8, 4, 512);
        let decodes = 4 * c.call_ns(Mode::W4A16, Phase::Decode, 8, 1, 512);
        assert!(verify < decodes / 2, "{verify} vs {decodes}");
    }

    #[test]
    fn quantized_kv_draft_cheaper_than_full_precision_decode() {
        // the HierSpec claim priced by the cost model: a W4A16 decode
        // step over a 4-bit shadow KV beats the same step over the
        // fp16 cache, and the saving grows with context (KV traffic
        // dominates weight traffic at long ctx)
        let c = cm();
        for ctx in [512usize, 2048] {
            let full = c.call_ns(Mode::W4A16, Phase::Decode, 16, 1, ctx);
            let shadow = c.call_ns_kv_bits(Mode::W4A16, Phase::Decode, 16, 1, ctx, 4);
            assert!(shadow < full, "ctx={ctx}: {shadow} !< {full}");
        }
        // width-16 shadow degenerates to the fp16 cache cost
        assert_eq!(
            c.call_ns_kv_bits(Mode::W4A16, Phase::Decode, 16, 1, 512, 16),
            c.call_ns(Mode::W4A16, Phase::Decode, 16, 1, 512)
        );
        // monotone in width
        let t = |bits| c.call_ns_kv_bits(Mode::W4A16, Phase::Decode, 16, 1, 2048, bits);
        assert!(t(2) < t(4) && t(4) < t(8));
    }

    #[test]
    fn charge_kv_bits_accumulates_like_charge() {
        let mut c = cm();
        let a = c.charge_kv_bits(Mode::W4A16, Phase::Decode, 8, 1, 512, 4);
        assert_eq!(c.virtual_ns, a);
        assert_eq!(a, c.call_ns_kv_bits(Mode::W4A16, Phase::Decode, 8, 1, 512, 4));
    }

    #[test]
    fn charge_accumulates() {
        let mut c = cm();
        let a = c.charge(Mode::W4A4, Phase::Decode, 8, 1, 128);
        let b = c.charge(Mode::W4A4, Phase::Decode, 8, 1, 128);
        assert_eq!(c.virtual_ns, a + b);
    }

    #[test]
    fn quantized_weights_quarter_size() {
        let c = cm();
        let fp = c.weight_bytes(Mode::W16A16);
        let q = c.weight_bytes(Mode::W4A16);
        assert!(q * 3 < fp, "{q} vs {fp}");
    }

    #[test]
    fn memory_check_oom() {
        let c = cm();
        assert!(c.check_memory(c.mem_budget + 1, "x").is_err());
        assert!(c.check_memory(c.mem_budget - 1, "x").is_ok());
    }
}
