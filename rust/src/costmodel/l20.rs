//! NVIDIA L20 device constants (paper testbed: 4x L20, 48 GB each).
//!
//! Public spec numbers; MFU chosen so absolute magnitudes are plausible —
//! the *ratios* between modes are what the reproduction relies on.

/// HBM capacity per GPU: 48 GB (paper runs up to 13B on one).
pub const HBM_BYTES: usize = 48 * (1 << 30);

/// GDDR6 bandwidth: 864 GB/s = 864 bytes/ns.
pub const HBM_BW_BYTES_PER_NS: f64 = 864.0;

/// FP16 tensor peak: 119.5 TFLOPS = 119.5 FLOP/ns... scaled to /ns:
pub const FP16_FLOPS_PER_NS: f64 = 119_500.0;

/// INT4 tensor peak (2x INT8 = 4x FP16 dense on Ada): 478 TOPS.
pub const INT4_OPS_PER_NS: f64 = 478_000.0;

/// Achievable fraction of peak in a serving kernel.
pub const MFU: f64 = 0.45;

/// Per-layer kernel-launch/dispatch overhead (ns).
pub const LAUNCH_OVERHEAD_NS: f64 = 4_000.0;
