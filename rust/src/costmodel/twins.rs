//! Paper-twin model descriptions: each local config (s/m/l/xl) is mapped
//! to the Llama model the paper evaluated, so virtual-time throughput is
//! reported at paper scale (DESIGN.md §3 substitution table).

use crate::model::Mode;

/// Architecture card of a paper-scale model.
#[derive(Clone, Debug)]
pub struct Twin {
    pub name: &'static str,
    pub n_params: usize,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
}

impl Twin {
    /// KV bytes per token per sequence. A16 caches are fp16; the W4A4
    /// baseline (Atom) also quantizes KV to int4. QSPEC always keeps the
    /// A16 cache (the KV-overwriting design), so engines pass the mode
    /// they *store* with.
    pub fn kv_bytes_per_token(&self, mode: Mode) -> usize {
        let elems = self.n_layers * 2 * self.n_kv_heads * self.head_dim;
        match mode {
            Mode::W4A4 => elems / 2, // int4 KV
            _ => elems * 2,          // fp16 KV
        }
    }

    /// KV bytes per token at an explicit storage width — the
    /// hierarchical shadow tier HierSpec drafts over (`--kv-bits`).
    /// `kv_bytes_per_token` keeps the mode-implied widths.
    pub fn kv_bytes_per_token_bits(&self, bits: u8) -> usize {
        let elems = self.n_layers * 2 * self.n_kv_heads * self.head_dim;
        (elems * bits as usize).div_ceil(8)
    }

    pub fn lookup(name: &str) -> Twin {
        match name {
            "llama3.2-3b" => Twin {
                name: "llama3.2-3b",
                n_params: 3_210_000_000,
                n_layers: 28,
                n_kv_heads: 8,
                head_dim: 128,
            },
            "llama2-7b" => Twin {
                name: "llama2-7b",
                n_params: 6_740_000_000,
                n_layers: 32,
                n_kv_heads: 32,
                head_dim: 128,
            },
            "llama3-8b" => Twin {
                name: "llama3-8b",
                n_params: 8_030_000_000,
                n_layers: 32,
                n_kv_heads: 8,
                head_dim: 128,
            },
            "llama2-13b" => Twin {
                name: "llama2-13b",
                n_params: 13_000_000_000,
                n_layers: 40,
                n_kv_heads: 40,
                head_dim: 128,
            },
            // EAGLE draft head: ~1 decoder layer + lm head over 7B dims
            "eagle-head" => Twin {
                name: "eagle-head",
                n_params: 440_000_000,
                n_layers: 1,
                n_kv_heads: 32,
                head_dim: 128,
            },
            // local tiny config for tests
            _ => Twin {
                name: "llama-1b",
                n_params: 1_100_000_000,
                n_layers: 16,
                n_kv_heads: 8,
                head_dim: 128,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twins_scale_monotonically() {
        let sizes = ["llama3.2-3b", "llama2-7b", "llama3-8b", "llama2-13b"];
        let params: Vec<usize> = sizes.iter().map(|s| Twin::lookup(s).n_params).collect();
        assert!(params.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn gqa_models_have_smaller_kv() {
        // llama3-8b (GQA, 8 kv heads) < llama2-7b (MHA, 32 kv heads)
        let gqa = Twin::lookup("llama3-8b").kv_bytes_per_token(Mode::W4A16);
        let mha = Twin::lookup("llama2-7b").kv_bytes_per_token(Mode::W4A16);
        assert!(gqa < mha);
    }

    #[test]
    fn int4_kv_half_of_fp16_quarter() {
        let t = Twin::lookup("llama2-7b");
        assert_eq!(
            t.kv_bytes_per_token(Mode::W4A4) * 4,
            t.kv_bytes_per_token(Mode::W4A16)
        );
    }

    #[test]
    fn explicit_bit_widths_match_mode_widths() {
        let t = Twin::lookup("llama2-7b");
        // 4-bit shadow == the W4A4 int4 cache; 16-bit == the fp16 cache
        assert_eq!(t.kv_bytes_per_token_bits(4), t.kv_bytes_per_token(Mode::W4A4));
        assert_eq!(t.kv_bytes_per_token_bits(16), t.kv_bytes_per_token(Mode::W4A16));
        // monotone in width
        assert!(t.kv_bytes_per_token_bits(2) < t.kv_bytes_per_token_bits(4));
        assert!(t.kv_bytes_per_token_bits(4) < t.kv_bytes_per_token_bits(8));
    }
}
