//! Property tests for the hierarchical (quantized-shadow) KV path —
//! `kvcache::QuantizedView` + the `SlotManager` shadow hooks HierSpec
//! drafts over.
//!
//! What must hold:
//!   1. quantize→dequantize round-trip error is bounded by the
//!      `kv_bits`-implied half step (`max_roundtrip_error`), for any
//!      in-range value at any supported width — and tighter widths
//!      never beat wider ones on the bound;
//!   2. after any interleaving of draft-phase speculation and
//!      verify-phase commits, the shadow is *consistent* with full
//!      precision (every committed code requantizes from the full
//!      value, no speculative residue) and tracks exactly the
//!      committed-entry count;
//!   3. `SlotManager::release` clears both tiers: the logical slot
//!      and its quantized view.

use qspec::kvcache::{kv_proxy, QuantizedView, SlotManager};
use qspec::util::check::check;
use qspec::util::prng::Pcg32;

#[test]
fn roundtrip_error_bounded_by_kv_bits() {
    check(
        "quant-roundtrip-bound",
        4000,
        |r: &mut Pcg32| {
            let bits = r.range_inclusive(2, 8);
            // values in [-1, 1] with some mass exactly on the ends
            let raw = r.below(1 << 20);
            (bits, raw)
        },
        |&(bits, raw)| {
            let bits = (bits.clamp(2, 8)) as u8;
            let v = (raw as f32 / (1 << 19) as f32) - 1.0;
            let code = QuantizedView::quantize(bits, v);
            let dq = QuantizedView::dequantize(bits, code);
            let bound = QuantizedView::max_roundtrip_error(bits);
            if (dq - v).abs() > bound + 1e-6 {
                return Err(format!(
                    "bits={bits} v={v}: |{dq} - {v}| = {} > bound {bound}",
                    (dq - v).abs()
                ));
            }
            // a wider shadow can only tighten the bound
            if bits < 8 {
                let wider = QuantizedView::max_roundtrip_error(bits + 1);
                if wider >= bound {
                    return Err(format!("bound not monotone: {wider} >= {bound}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn mean_error_shrinks_with_width() {
    // aggregate, not per-sample: the *mean* round-trip error over a
    // fixed value population must strictly shrink as the shadow widens
    // (the signal HierSpec's draft lossiness is driven by)
    let values: Vec<f32> = (0..512).map(|i| kv_proxy(i, i as usize)).collect();
    let mean_err = |bits: u8| -> f32 {
        values
            .iter()
            .map(|&v| {
                (v - QuantizedView::dequantize(bits, QuantizedView::quantize(bits, v))).abs()
            })
            .sum::<f32>()
            / values.len() as f32
    };
    let errs: Vec<f32> = [2u8, 4, 6, 8].iter().map(|&b| mean_err(b)).collect();
    for w in errs.windows(2) {
        assert!(w[1] < w[0], "mean error must shrink with width: {errs:?}");
    }
    // and the 4-bit mean sits well under the worst-case bound
    assert!(errs[1] < QuantizedView::max_roundtrip_error(4));
}

/// One random slot lifecycle: admit → prefill → interleaved
/// speculate/commit rounds → the shadow invariants, then release.
#[test]
fn shadow_consistent_under_random_speculate_commit_interleavings() {
    check(
        "shadow-consistency",
        500,
        |r: &mut Pcg32| {
            let bits = r.range_inclusive(2, 8);
            let rounds = r.range_inclusive(1, 10);
            let raw: Vec<u32> = (0..(rounds * 8) as usize).map(|_| r.next_u32()).collect();
            (bits, raw)
        },
        |(bits, raw)| {
            let bits = (*bits).clamp(2, 8) as u8;
            let mut m = SlotManager::with_shadow(2, 4096, 16, bits);
            let idx = m.admit(7, &[1, 2, 3, 4], 100_000, vec![]).map_err(|e| e.to_string())?;
            m.after_prefill(idx, 11, -1); // EOS -1: never matched
            let mut expected_committed = 1usize;
            let mut draws = raw.iter().copied().peekable();
            while draws.peek().is_some() {
                // draft phase: speculate 0..=3 entries
                let n_spec = (draws.next().unwrap() % 4) as usize;
                let spec: Vec<i32> =
                    (0..n_spec).map(|_| (draws.next().unwrap_or(1) % 64) as i32).collect();
                m.shadow_speculate(idx, &spec);
                let v = m.shadow_view(idx).unwrap();
                if v.speculative_len() != spec.len() {
                    return Err(format!(
                        "speculative {} != drafted {}",
                        v.speculative_len(),
                        spec.len()
                    ));
                }
                // verify phase: commit 1..=4 tokens (rolls speculation back)
                let n_commit = (draws.next().unwrap_or(1) % 4 + 1) as usize;
                let toks: Vec<i32> =
                    (0..n_commit).map(|_| (draws.next().unwrap_or(1) % 64) as i32).collect();
                let committed = m.commit(idx, &toks, -1, 4);
                expected_committed += committed.len();

                let v = m.shadow_view(idx).unwrap();
                if v.speculative_len() != 0 {
                    return Err("verify left speculative residue".into());
                }
                if v.committed_len() != expected_committed {
                    return Err(format!(
                        "shadow tracks {} entries, committed {expected_committed}",
                        v.committed_len()
                    ));
                }
                if !v.is_consistent() {
                    return Err("shadow codes diverge from full precision".into());
                }
                // every committed entry requantizes from the exact
                // full-precision proxy: the dequantized tier is within
                // the bits-implied bound of the full tier
                let bound = QuantizedView::max_roundtrip_error(bits);
                for i in 0..v.committed_len() {
                    if (v.full(i) - v.dequantized(i)).abs() > bound + 1e-6 {
                        return Err(format!("entry {i} outside the {bits}-bit bound"));
                    }
                }
                if m.shadow_error(idx) > bound {
                    return Err("mean error exceeds the worst-case bound".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn release_clears_both_tiers_and_next_request_starts_clean() {
    let mut m = SlotManager::with_shadow(1, 256, 16, 4);
    let idx = m.admit(1, &[1, 2, 3, 4], 100, vec![]).unwrap();
    m.after_prefill(idx, 5, -1);
    m.shadow_speculate(idx, &[6, 7, 8]);
    m.commit(idx, &[6, 9], -1, 3);
    assert!(m.shadow_view(idx).unwrap().committed_len() > 0);

    let (id, toks) = m.release(idx).expect("release");
    assert_eq!(id, 1);
    assert_eq!(toks, vec![5, 6, 9]);
    // both tiers cleared: logical slot free, shadow empty
    assert!(m.free_slots().any(|f| f == idx));
    let v = m.shadow_view(idx).unwrap();
    assert_eq!(v.committed_len(), 0);
    assert_eq!(v.speculative_len(), 0);
    assert_eq!(m.shadow_error(idx), 0.0);

    // the slot is immediately reusable with a pristine shadow
    let idx2 = m.admit(2, &[1, 2, 3, 4], 100, vec![]).unwrap();
    assert_eq!(idx2, idx);
    assert_eq!(m.shadow_view(idx2).unwrap().committed_len(), 0);
    assert!(m.shadow_view(idx2).unwrap().is_consistent());
}

#[test]
fn speculative_entries_are_lossy_until_verified() {
    // a speculative (draft-written) entry lives at draft precision in
    // both tiers; the verify overwrite restores the exact full value
    let mut v = QuantizedView::new(2); // coarse: loss is visible
    let exact = 0.3337f32;
    v.speculate(exact);
    // the full tier holds the *dequantized* value while speculative
    assert_ne!(v.full(0), exact, "draft writes are lossy");
    assert_eq!(v.full(0), v.dequantized(0));
    v.rollback_speculative();
    v.commit_overwrite(exact);
    assert_eq!(v.full(0), exact, "verify restores full precision");
    assert!(v.is_consistent());
    assert_eq!(v.committed_len(), 1);
}
