//! Property tests for the paged KV layer — `kvcache::block::BlockAllocator`,
//! `kvcache::prefix::RadixPrefixCache`, and the `SlotManager` paging
//! built on them (companion to `kv_quant_props.rs`, which covers the
//! quantized shadow tier itself).
//!
//! What must hold:
//!   1. the allocator agrees with a plain refcount model under random
//!      alloc/retain/release/push sequences — no double-free (a freed
//!      block never resurfaces while the model holds it live), no
//!      refcount underflow, and the free/live accounting always sums
//!      to capacity;
//!   2. copy-on-write divergence preserves the shared prefix: after
//!      two sequences fork off a common cached prompt and commit
//!      different tails, each reads back exactly its own stream and
//!      the attached prefix blocks still hold the original bytes;
//!   3. `longest_match` returns exactly the longest cached prefix, at
//!      block granularity, against a brute-force reference over every
//!      inserted stream;
//!   4. LRU eviction only ever reclaims blocks whose last holder is
//!      the cache — a block still referenced by a (simulated) live
//!      slot survives any amount of eviction pressure, bytes intact;
//!   5. with a quantized shadow tier, shadow codes page together with
//!      the full blocks under random admit/speculate/commit/release
//!      interleavings: one code per token, each requantizing from the
//!      token's full-precision proxy at its stream position;
//!   6. end to end through `BatchCore`: a follow-up request sharing a
//!      committed prefix is admitted with its matched blocks attached,
//!      so prefill is priced on the uncached remainder only and the
//!      hit shows up in the engine metrics;
//!   7. tree-shaped CoW (v1.7 TreeSpec): sibling branches forked off a
//!      shared committed prefix allocate no duplicate blocks for that
//!      prefix, diverge only on write (interleaved appends copy only
//!      tail blocks, parent bytes intact), and release frees exactly
//!      the non-shared blocks — refcounts audited block by block.

use std::collections::HashMap;

use qspec::coordinator::BatchCore;
use qspec::costmodel::{twins::Twin, CostModel};
use qspec::kvcache::block::{BlockAllocator, BlockId};
use qspec::kvcache::prefix::RadixPrefixCache;
use qspec::kvcache::{kv_proxy, QuantizedView, SlotManager};
use qspec::util::check::check;
use qspec::util::prng::Pcg32;

/// Fill full+tail blocks with `stream` tokens (the slot-side half of a
/// cache insert); returns the block table. The caller owns one ref per
/// block, standing in for a live slot's table.
fn fill(alloc: &mut BlockAllocator, stream: &[i32]) -> Vec<BlockId> {
    let bs = alloc.block_size();
    let mut table = Vec::new();
    for (j, &t) in stream.iter().enumerate() {
        if j % bs == 0 {
            table.push(alloc.alloc().expect("test pool sized generously"));
        }
        alloc.push(*table.last().unwrap(), t, None);
    }
    table
}

#[test]
fn block_allocator_agrees_with_a_refcount_model() {
    check(
        "block-allocator-model",
        400,
        |r: &mut Pcg32| {
            let ops: Vec<u32> = (0..r.range_inclusive(10, 120)).map(|_| r.next_u32()).collect();
            ops
        },
        |ops| {
            const CAP: usize = 8;
            let mut a = BlockAllocator::new(4, CAP);
            // the reference: live block -> refcount (absent = free)
            let mut model: HashMap<BlockId, u32> = HashMap::new();
            let live_pick = |model: &HashMap<BlockId, u32>, draw: u32| -> Option<BlockId> {
                let mut live: Vec<BlockId> = model.keys().copied().collect();
                live.sort_unstable();
                if live.is_empty() {
                    None
                } else {
                    Some(live[draw as usize % live.len()])
                }
            };
            for op in ops {
                match op % 4 {
                    0 => {
                        let got = a.alloc();
                        if model.len() == CAP {
                            if got.is_some() {
                                return Err("alloc succeeded past capacity".into());
                            }
                        } else {
                            let id = got.ok_or("alloc failed below capacity")?;
                            if model.contains_key(&id) {
                                return Err(format!("alloc returned live block {id}"));
                            }
                            if !a.is_empty(id) {
                                return Err(format!("alloc returned dirty block {id}"));
                            }
                            model.insert(id, 1);
                        }
                    }
                    1 => {
                        if let Some(id) = live_pick(&model, op / 4) {
                            a.retain(id);
                            *model.get_mut(&id).unwrap() += 1;
                        }
                    }
                    2 => {
                        if let Some(id) = live_pick(&model, op / 4) {
                            // the model never double-frees, so release
                            // must never trap (no underflow)
                            a.release(id);
                            let rc = model.get_mut(&id).unwrap();
                            *rc -= 1;
                            if *rc == 0 {
                                model.remove(&id);
                            }
                        }
                    }
                    _ => {
                        // writes only into exclusively owned, non-full blocks
                        if let Some(id) = live_pick(&model, op / 4) {
                            if model[&id] == 1 && !a.is_full(id) {
                                a.push(id, (op % 97) as i32, None);
                            }
                        }
                    }
                }
                if a.free_count() + a.live_count() != CAP {
                    return Err(format!(
                        "accounting broke: {} free + {} live != {CAP}",
                        a.free_count(),
                        a.live_count()
                    ));
                }
                if a.live_count() != model.len() {
                    return Err(format!(
                        "allocator holds {} live, model {}",
                        a.live_count(),
                        model.len()
                    ));
                }
                for (&id, &rc) in &model {
                    if a.refcount(id) != rc {
                        return Err(format!(
                            "block {id}: refcount {} != model {rc}",
                            a.refcount(id)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn cow_divergence_preserves_shared_prefix_bytes() {
    check(
        "cow-shared-prefix",
        300,
        |r: &mut Pcg32| {
            let bs = r.range_inclusive(1, 4);
            let plen = r.range_inclusive(2, 12);
            let tails: Vec<u32> = (0..16).map(|_| r.next_u32()).collect();
            (bs, (plen, tails))
        },
        |(bs, (plen, tails))| {
            let bs = (*bs).clamp(1, 4) as usize;
            let plen = (*plen).clamp(2, 12) as usize;
            let mut m = SlotManager::new(2, 512, 16);
            m.configure_paging(bs, true);
            let prompt: Vec<i32> = (0..plen as i32).map(|j| j % 7).collect();
            // seed the cache: one request commits the prompt and releases
            let i = m.admit(1, &prompt, 64, vec![]).map_err(|e| e.to_string())?;
            m.after_prefill(i, 50, -1);
            m.release(i).expect("seed slot releases");
            // two sequences fork off the shared prefix...
            let a = m.admit(2, &prompt, 64, vec![]).map_err(|e| e.to_string())?;
            let b = m.admit(3, &prompt, 64, vec![]).map_err(|e| e.to_string())?;
            let shared = m.slot(a).cached / bs;
            if m.block_table(a)[..shared] != m.block_table(b)[..shared] {
                return Err("matched prefix blocks not shared".into());
            }
            m.after_prefill(a, 60, -1);
            m.after_prefill(b, 70, -1);
            // ...and commit different tails
            let mut expect_a = [prompt.clone(), vec![60]].concat();
            let mut expect_b = [prompt.clone(), vec![70]].concat();
            for (j, &t) in tails.iter().enumerate() {
                let tok = (t % 41) as i32 + 100;
                if j % 2 == 0 {
                    expect_a.extend(m.commit(a, &[tok], -1, 4));
                } else {
                    expect_b.extend(m.commit(b, &[tok + 1], -1, 4));
                }
            }
            // each table reads back exactly its own stream
            for (idx, expect) in [(a, &expect_a), (b, &expect_b)] {
                let got: Vec<i32> =
                    m.block_table(idx).iter().flat_map(|&id| m.block_tokens(id)).copied().collect();
                if &got != expect {
                    return Err(format!("slot {idx}: paged {got:?}, committed {expect:?}"));
                }
            }
            // and the blocks the fork shared still hold the prompt bytes
            let cached: Vec<i32> = m.block_table(a)[..shared]
                .iter()
                .flat_map(|&id| m.block_tokens(id))
                .copied()
                .collect();
            if cached != prompt[..shared * bs] {
                return Err("divergence corrupted the shared prefix".into());
            }
            Ok(())
        },
    );
}

#[test]
fn longest_match_agrees_with_a_reference_model() {
    check(
        "radix-longest-match",
        400,
        |r: &mut Pcg32| {
            let bs = r.range_inclusive(1, 3);
            // tiny alphabet + short streams force heavy prefix overlap
            let draws: Vec<u32> = (0..40).map(|_| r.below(1 << 16)).collect();
            (bs, draws)
        },
        |(bs, draws)| {
            let bs = (*bs).clamp(1, 3) as usize;
            let mut alloc = BlockAllocator::new(bs, 256);
            let mut c = RadixPrefixCache::new();
            let mut streams: Vec<Vec<i32>> = Vec::new();
            let mut d = draws.iter().copied();
            for _ in 0..6 {
                let len = (d.next().unwrap_or(3) % 8 + 1) as usize;
                let s: Vec<i32> = (0..len).map(|_| (d.next().unwrap_or(0) % 3) as i32).collect();
                let table = fill(&mut alloc, &s);
                c.insert(&s, &table, &mut alloc);
                streams.push(s);
            }
            for _ in 0..8 {
                let len = (d.next().unwrap_or(3) % 9) as usize;
                let probe: Vec<i32> =
                    (0..len).map(|_| (d.next().unwrap_or(0) % 3) as i32).collect();
                // reference: longest run of full blocks any inserted
                // stream shares with the probe
                let expected = streams
                    .iter()
                    .map(|s| {
                        let mut k = 0;
                        while (k + 1) * bs <= s.len().min(probe.len())
                            && s[k * bs..(k + 1) * bs] == probe[k * bs..(k + 1) * bs]
                        {
                            k += 1;
                        }
                        k
                    })
                    .max()
                    .unwrap_or(0);
                let got = c.longest_match(&probe, bs);
                if got.len() != expected {
                    return Err(format!(
                        "probe {probe:?}: matched {} blocks, reference {expected}",
                        got.len()
                    ));
                }
                let toks: Vec<i32> =
                    got.iter().flat_map(|&id| alloc.tokens(id)).copied().collect();
                if toks != probe[..expected * bs] {
                    return Err(format!("matched blocks hold {toks:?}, probe {probe:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn eviction_never_frees_slot_referenced_blocks() {
    check(
        "radix-eviction-safety",
        300,
        |r: &mut Pcg32| {
            let bs = r.range_inclusive(1, 3);
            let draws: Vec<u32> = (0..32).map(|_| r.next_u32()).collect();
            (bs, draws)
        },
        |(bs, draws)| {
            let bs = (*bs).clamp(1, 3) as usize;
            let mut alloc = BlockAllocator::new(bs, 256);
            let mut c = RadixPrefixCache::new();
            let mut d = draws.iter().copied();
            // insert a handful of overlapping streams; every other one
            // keeps its slot reference (a live sequence), the rest
            // release theirs so the cache becomes the last holder
            let mut held: Vec<(BlockId, Vec<i32>)> = Vec::new();
            for k in 0..6 {
                let len = (d.next().unwrap_or(3) % 8 + 1) as usize;
                let s: Vec<i32> = (0..len).map(|_| (d.next().unwrap_or(0) % 3) as i32).collect();
                let table = fill(&mut alloc, &s);
                c.insert(&s, &table, &mut alloc);
                for &id in &table {
                    if k % 2 == 0 {
                        held.push((id, alloc.tokens(id).to_vec()));
                    } else {
                        alloc.release(id);
                    }
                }
            }
            // drain the cache under full eviction pressure
            let mut evictions = 0;
            while c.evict_one(&mut alloc) {
                evictions += 1;
                if evictions > 256 {
                    return Err("eviction failed to terminate".into());
                }
                for (id, toks) in &held {
                    if alloc.refcount(*id) == 0 {
                        return Err(format!("evicted slot-held block {id}"));
                    }
                    if alloc.tokens(*id) != toks {
                        return Err(format!("eviction corrupted held block {id}"));
                    }
                }
            }
            // fixpoint: everything still cached is pinned by a holder
            // (directly, or through a held descendant's matched path)
            for (id, toks) in &held {
                if alloc.refcount(*id) == 0 || alloc.tokens(*id) != toks {
                    return Err(format!("held block {id} lost after drain"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shadow_blocks_stay_consistent_under_random_interleavings() {
    check(
        "paged-shadow-consistency",
        250,
        |r: &mut Pcg32| {
            let bits = r.range_inclusive(2, 8);
            let ops: Vec<u32> = (0..r.range_inclusive(10, 60)).map(|_| r.next_u32()).collect();
            (bits, ops)
        },
        |(bits, ops)| {
            let bits = (*bits).clamp(2, 8) as u8;
            let mut m = SlotManager::with_shadow(2, 512, 16, bits);
            m.configure_paging(2, true);
            // per-slot expected logical stream (prompt + generated)
            let mut expect: [Option<Vec<i32>>; 2] = [None, None];
            let mut next_id = 1u64;
            for &op in ops {
                let slot = (op as usize / 4) % 2;
                match op % 4 {
                    0 => {
                        // admit picks the first free slot itself; track
                        // whichever index it lands on
                        if m.free_slots().next().is_some() {
                            let plen = (op / 8) as usize % 8 + 1;
                            let prompt: Vec<i32> =
                                (0..plen as i32).map(|j| (j + (op % 5) as i32) % 9).collect();
                            let idx = m
                                .admit(next_id, &prompt, 6 + (op as usize / 16) % 10, vec![])
                                .map_err(|e| e.to_string())?;
                            next_id += 1;
                            let first = (op / 32 % 9) as i32 + 10;
                            m.after_prefill(idx, first, -1);
                            let mut s = prompt;
                            s.push(first);
                            expect[idx] = Some(s);
                        }
                    }
                    1 => {
                        if expect[slot].is_some() && !m.slot(slot).done {
                            let n = (op / 8) as usize % 3 + 1;
                            let toks: Vec<i32> =
                                (0..n).map(|j| (op / 16 % 9) as i32 + j as i32 + 20).collect();
                            let committed = m.commit(slot, &toks, -1, 4);
                            expect[slot].as_mut().unwrap().extend(committed);
                        }
                    }
                    2 => {
                        if expect[slot].is_some() {
                            // draft-phase speculation touches only the
                            // shadow view, never the paged blocks
                            m.shadow_speculate(slot, &[3, 4]);
                            if !m.slot(slot).done {
                                let committed = m.commit(slot, &[5], -1, 4);
                                expect[slot].as_mut().unwrap().extend(committed);
                            }
                        }
                    }
                    _ => {
                        if expect[slot].is_some() {
                            m.release(slot).expect("occupied slot releases");
                            expect[slot] = None;
                        }
                    }
                }
                check_streams(&m, &expect, bits)?;
            }
            Ok(())
        },
    );
}

/// Both tiers of every live slot page the same stream: block tokens
/// concatenate to the expected run, and each shadow code requantizes
/// from the token's full-precision proxy at its stream position.
fn check_streams(
    m: &SlotManager,
    expect: &[Option<Vec<i32>>; 2],
    bits: u8,
) -> Result<(), String> {
    for (slot, want) in expect.iter().enumerate() {
        let Some(want) = want else { continue };
        let mut pos = 0usize;
        for &id in m.block_table(slot) {
            let toks = m.block_tokens(id);
            let codes = m.block_shadow_codes(id);
            if codes.len() != toks.len() {
                return Err(format!("block {id}: {} codes, {} tokens", codes.len(), toks.len()));
            }
            for (&code, &tok) in codes.iter().zip(toks) {
                if want.get(pos) != Some(&tok) {
                    return Err(format!(
                        "slot {slot} pos {pos}: paged {tok}, committed {:?}",
                        want.get(pos)
                    ));
                }
                if code != QuantizedView::quantize(bits, kv_proxy(tok, pos)) {
                    return Err(format!("slot {slot} pos {pos}: stale shadow code"));
                }
                pos += 1;
            }
        }
        if pos != want.len() {
            return Err(format!("slot {slot}: paged {pos} of {} tokens", want.len()));
        }
    }
    Ok(())
}

/// Property 7 — the TreeSpec fork pattern: every cycle the engine
/// forks one branch per non-principal tree node off the slot's
/// committed stream, appends that branch's divergent path, then
/// releases all branches before committing. Under random shapes the
/// pager must (a) share every prefix block at fork time (zero
/// allocation), (b) copy only tail blocks on write, sibling by
/// sibling, (c) leave the parent's bytes untouched, and (d) on
/// release free exactly the non-shared blocks, restoring the
/// pre-fork refcounts.
#[test]
fn tree_branch_forks_share_prefix_and_release_exactly_non_shared() {
    check(
        "tree-branch-cow",
        300,
        |r: &mut Pcg32| {
            let bs = r.range_inclusive(1, 4);
            let plen = r.range_inclusive(2, 10);
            let branches = r.range_inclusive(1, 4);
            let appends: Vec<u32> = (0..4).map(|_| r.next_u32()).collect();
            (bs, (plen, (branches, appends)))
        },
        |(bs, (plen, (branches, appends)))| {
            let bs = (*bs).clamp(1, 4) as usize;
            let plen = (*plen).clamp(2, 10) as usize;
            let nb = (*branches).clamp(1, 4) as usize;
            let mut m = SlotManager::new(1, 512, 64);
            m.configure_paging(bs, true);
            let prompt: Vec<i32> = (0..plen as i32).collect();
            let i = m.admit(1, &prompt, 64, vec![]).map_err(|e| e.to_string())?;
            m.after_prefill(i, 50, -1);
            let parent_stream = [prompt.clone(), vec![50]].concat();
            let parent_table = m.block_table(i).to_vec();
            let rc0: Vec<u32> = parent_table.iter().map(|&b| m.block_refcount(b)).collect();
            let baseline = m.live_blocks();
            let read_parent = |m: &SlotManager| -> Vec<i32> {
                m.block_table(i).iter().flat_map(|&id| m.block_tokens(id)).copied().collect()
            };
            let read_branch = |m: &SlotManager, b: usize| -> Vec<i32> {
                m.branch_blocks(b).iter().flat_map(|&id| m.block_tokens(id)).copied().collect()
            };

            // (a) fork: every branch shares every parent block by
            // refcount; the forks themselves allocate nothing
            let ids: Vec<usize> = (0..nb).map(|_| m.fork_branch(i)).collect();
            if m.live_branches() != nb {
                return Err(format!("{} live branches after {nb} forks", m.live_branches()));
            }
            if m.live_blocks() != baseline {
                return Err("forking allocated blocks for an unchanged stream".into());
            }
            for &b in &ids {
                if m.branch_blocks(b) != parent_table.as_slice() {
                    return Err(format!("branch {b} does not share the parent table"));
                }
                if m.branch_len(b) != parent_stream.len() {
                    return Err(format!("branch {b} stream length diverged at fork"));
                }
            }
            for (k, &blk) in parent_table.iter().enumerate() {
                let want = rc0[k] + nb as u32;
                if m.block_refcount(blk) != want {
                    return Err(format!(
                        "block {blk}: refcount {} != {want} after {nb} forks",
                        m.block_refcount(blk)
                    ));
                }
            }

            // (b) diverge: interleaved round-robin appends, so siblings
            // CoW off the same partial tail one after another
            let goal: Vec<usize> =
                (0..nb).map(|j| (appends[j % appends.len()] as usize % 4) + 1).collect();
            let mut want: Vec<Vec<i32>> = vec![parent_stream.clone(); nb];
            for round in 0..4usize {
                for (j, &b) in ids.iter().enumerate() {
                    if round < goal[j] {
                        let tok = 100 + (j * 10 + round) as i32;
                        m.branch_append(b, tok);
                        want[j].push(tok);
                    }
                }
            }
            // every branch reads back exactly its own path; the parent
            // and the full prefix blocks are untouched and still shared
            for (j, &b) in ids.iter().enumerate() {
                if read_branch(&m, b) != want[j] {
                    return Err(format!(
                        "branch {b}: paged {:?}, appended {:?}",
                        read_branch(&m, b),
                        want[j]
                    ));
                }
                let shared = parent_stream.len() / bs; // full blocks only
                if m.branch_blocks(b)[..shared] != parent_table[..shared] {
                    return Err(format!("branch {b} duplicated shared prefix blocks"));
                }
            }
            if read_parent(&m) != parent_stream {
                return Err("branch writes leaked into the parent stream".into());
            }
            // exact allocation accounting: each branch owns only its
            // diverged tail — ceil(len/bs) total blocks minus the full
            // parent blocks it still shares
            let fresh: usize = (0..nb)
                .map(|j| want[j].len().div_ceil(bs) - parent_stream.len() / bs)
                .sum();
            if m.live_blocks() != baseline + fresh {
                return Err(format!(
                    "{} live blocks; shared prefix should cap it at {baseline} + {fresh}",
                    m.live_blocks()
                ));
            }

            // (d) release in a scrambled order: each release frees only
            // that branch's non-shared tail; the full drain restores
            // the exact pre-fork state
            for (n, &b) in ids.iter().rev().enumerate() {
                m.release_branch(b);
                if read_parent(&m) != parent_stream {
                    return Err("branch release corrupted the parent stream".into());
                }
                if m.live_branches() != nb - 1 - n {
                    return Err("live branch count out of step".into());
                }
            }
            if m.live_blocks() != baseline {
                return Err(format!(
                    "{} live blocks after drain, {baseline} before forking",
                    m.live_blocks()
                ));
            }
            for (k, &blk) in parent_table.iter().enumerate() {
                if m.block_refcount(blk) != rc0[k] {
                    return Err(format!("block {blk}: refcount not restored after drain"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn follow_up_admission_prices_prefill_on_uncached_tokens_only() {
    let mut core = BatchCore::new(
        SlotManager::new(1, 256, 16),
        CostModel::new(Twin::lookup("llama2-7b")),
    );
    core.slots.configure_paging(4, true);
    let prompt: Vec<i32> = (1..=16).collect();
    let mut out = Vec::new();

    // cold cache: the whole prompt prefills
    core.submit(prompt.clone(), 2);
    let pb = core.admit_batch(&mut out).unwrap().expect("admission");
    assert_eq!(pb.uncached, vec![16]);
    assert_eq!(pb.uncached_tokens(), 16);
    core.finish_prefill(&pb, &[10], &mut out);
    let idx = pb.admitted[0].0;
    core.commit(idx, &[11], 4, &mut out); // budget 2 -> done, slot released
    assert_eq!(core.metrics.prefix_queries, 1);
    assert_eq!(core.metrics.prefix_hit_tokens, 0);

    // follow-up sharing the full prompt: all four kv_block-4 blocks are
    // cached; three attach (the last prompt token always prefills), so
    // the prefill call is priced on 4 tokens instead of 16
    core.submit(prompt, 2);
    let pb2 = core.admit_batch(&mut out).unwrap().expect("admission");
    assert_eq!(pb2.uncached, vec![4], "12 of 16 prompt tokens skipped prefill");
    assert_eq!(pb2.uncached_tokens(), 4);
    assert_eq!(core.metrics.prefix_queries, 2);
    assert_eq!(core.metrics.prefix_hit_tokens, 12);
    assert_eq!(core.metrics.prefix_hit_rate_opt(), Some(6.0));
}

#[test]
fn disabled_prefix_cache_never_skips_and_never_counts() {
    let mut core = BatchCore::new(
        SlotManager::new(1, 256, 16),
        CostModel::new(Twin::lookup("llama2-7b")),
    );
    core.slots.configure_paging(4, false);
    let prompt: Vec<i32> = (1..=16).collect();
    let mut out = Vec::new();
    for _ in 0..2 {
        core.submit(prompt.clone(), 2);
        let pb = core.admit_batch(&mut out).unwrap().expect("admission");
        assert_eq!(pb.uncached, vec![16], "cache off: full prefill every time");
        core.finish_prefill(&pb, &[10], &mut out);
        core.commit(pb.admitted[0].0, &[11], 4, &mut out);
    }
    assert_eq!(core.metrics.prefix_queries, 0, "disabled cache runs no lookups");
    assert_eq!(core.metrics.prefix_hit_rate_opt(), None);
}
