//! End-to-end integration tests over the real artifacts (tiny + s size).
//!
//! Require `make artifacts` to have run; they exercise the full
//! runtime -> engine -> acceptance -> KV-overwriting path on the CPU
//! PJRT client. One #[test] drives everything (PJRT client creation is
//! expensive and the handles are not Send, so a single test owns it).

use std::path::PathBuf;

use qspec::coordinator::{ArEngine, EagleConfig, EagleEngine, Engine, QSpecConfig, QSpecEngine};
use qspec::error::QspecError;
use qspec::evalsuite;
use qspec::model::{Mode, Tokenizer};
use qspec::runtime::{ArtifactStore, Session};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_root().join("manifest.json").exists()
}

#[test]
fn end_to_end_suite() {
    if !have_artifacts() {
        eprintln!("skipping integration: run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::open(&artifacts_root()).expect("manifest");
    let sess = Session::new(store).expect("session");
    let tok = Tokenizer::load(&sess.store.tokenizer_path()).expect("tokenizer");

    check_manifest_sanity(&sess);
    let ar_out = check_ar_generation(&sess, &tok);
    check_qspec_losslessness(&sess, &tok, &ar_out);
    check_qspec_acceptance_dynamics(&sess, &tok);
    check_continuous_batching_refill(&sess, &tok);
    check_no_overwrite_ablation(&sess, &tok);
    check_eagle_baseline_and_oom(&sess, &tok);
    check_perplexity_ordering(&sess);
}

fn check_manifest_sanity(sess: &Session) {
    let m = &sess.store.manifest;
    assert!(m.modules.len() >= 100, "expected full manifest");
    assert!(m.models.contains_key("tiny") && m.models.contains_key("s"));
    assert_eq!(m.gamma_default, 3);
}

/// W4A16 AR baseline generates deterministic, task-shaped output.
fn check_ar_generation(sess: &Session, tok: &Tokenizer) -> Vec<String> {
    let mut e = ArEngine::new(sess, "s", "atom", Mode::W4A16, 8).expect("ar engine");
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval set");
    let items = &items[..8];
    for it in items {
        e.submit(tok.encode_prompt(&it.prompt), 64);
    }
    let mut fins = e.run_to_completion().expect("ar run");
    fins.sort_by_key(|f| f.id);
    assert_eq!(fins.len(), 8);
    let texts: Vec<String> = fins.iter().map(|f| tok.decode(&f.tokens)).collect();
    // the trained model must produce step-formatted output
    let with_answer = texts.iter().filter(|t| t.contains("a: ")).count();
    assert!(with_answer >= 6, "model output unstructured: {texts:?}");
    texts
}

/// The paper's losslessness claim: QSPEC greedy output == W4A16 greedy
/// output. Chunked-vs-single-step float reductions can flip rare argmax
/// ties, so we require near-perfect agreement rather than bit equality.
fn check_qspec_losslessness(sess: &Session, tok: &Tokenizer, ar_out: &[String]) {
    let mut q = QSpecEngine::new(sess, QSpecConfig::new("s", 8)).expect("qspec engine");
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval");
    let items = &items[..8];
    for it in items {
        q.submit(tok.encode_prompt(&it.prompt), 64);
    }
    let mut fins = q.run_to_completion().expect("qspec run");
    fins.sort_by_key(|f| f.id);
    let texts: Vec<String> = fins.iter().map(|f| tok.decode(&f.tokens)).collect();
    let same = texts.iter().zip(ar_out).filter(|(a, b)| a == b).count();
    assert!(
        same >= 7,
        "QSPEC diverged from W4A16 on {}/8 prompts:\nqspec={texts:?}\nar={ar_out:?}",
        8 - same
    );
}

/// Acceptance must be high (the paper's core observation) and the
/// invariant committed == accepted + cycles must hold.
fn check_qspec_acceptance_dynamics(sess: &Session, tok: &Tokenizer) {
    let mut cfg = QSpecConfig::new("s", 8);
    cfg.collect_similarity = true;
    let mut q = QSpecEngine::new(sess, cfg).expect("engine");
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval");
    for it in &items[..16] {
        q.submit(tok.encode_prompt(&it.prompt), 64);
    }
    q.run_to_completion().expect("run");
    let acc = q.metrics().acceptance_rate();
    assert!(acc > 0.5, "acceptance rate {acc} too low for shared-weight drafting");
    assert!(q.metrics().drafted > 0);
    // verify-phase bookkeeping: every cycle commits accepted+1 tokens
    // (prefill adds 1 more per request)
    assert!(q.metrics().committed >= q.metrics().accepted);
    // fig2 samples: accepted tokens should carry high verify prob
    assert!(!q.samples.is_empty());
    let acc_mean: f32 = {
        let a: Vec<f32> = q
            .samples
            .iter()
            .filter(|s| s.accepted)
            .map(|s| s.p_verify)
            .collect();
        a.iter().sum::<f32>() / a.len().max(1) as f32
    };
    let rej: Vec<f32> = q
        .samples
        .iter()
        .filter(|s| !s.accepted)
        .map(|s| s.p_verify)
        .collect();
    if !rej.is_empty() {
        let rej_mean = rej.iter().sum::<f32>() / rej.len() as f32;
        assert!(
            acc_mean > rej_mean,
            "accepted tokens should have higher verify prob ({acc_mean} vs {rej_mean})"
        );
    }
}

/// More requests than slots: the batcher must refill and finish all in
/// FCFS admission order.
fn check_continuous_batching_refill(sess: &Session, tok: &Tokenizer) {
    let mut q = QSpecEngine::new(sess, QSpecConfig::new("s", 8)).expect("engine");
    let n = 20;
    let items = evalsuite::load_eval(&sess.store.eval_path("cloze")).expect("eval");
    for it in items.iter().take(n) {
        q.submit(tok.encode_prompt(&it.prompt), 16);
    }
    let fins = q.run_to_completion().expect("run");
    assert_eq!(fins.len(), n, "all requests must finish");
    let mut ids: Vec<u64> = fins.iter().map(|f| f.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n as u64).collect::<Vec<_>>());
    assert_eq!(q.metrics().requests_done, n as u64);
}

/// The no-overwrite ablation must not crash and should accept no more
/// than the overwriting configuration (paper Table 2: ~0.8x).
fn check_no_overwrite_ablation(sess: &Session, tok: &Tokenizer) {
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval");
    let run = |overwrite: bool| {
        let mut cfg = QSpecConfig::new("s", 8);
        cfg.overwrite = overwrite;
        let mut q = QSpecEngine::new(sess, cfg).expect("engine");
        for it in &items[..12] {
            q.submit(tok.encode_prompt(&it.prompt), 48);
        }
        q.run_to_completion().expect("run");
        q.metrics().acceptance_rate()
    };
    let with = run(true);
    let without = run(false);
    assert!(
        without <= with + 0.05,
        "no-overwrite should not beat overwriting: {without} vs {with}"
    );
}

/// EAGLE baseline runs at batch 8 and OOMs (simulated) with trees at 16.
fn check_eagle_baseline_and_oom(sess: &Session, tok: &Tokenizer) {
    let mut e = EagleEngine::new(sess, EagleConfig::new(8, 1)).expect("eagle b8");
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval");
    for it in &items[..8] {
        e.submit(tok.encode_prompt(&it.prompt), 32);
    }
    let fins = e.run_to_completion().expect("eagle run");
    assert_eq!(fins.len(), 8);
    // two-model drafting accepts less than shared-weight QSPEC
    assert!(e.metrics().drafted > 0);

    match EagleEngine::new(sess, EagleConfig::new(16, 2)) {
        Err(QspecError::Oom(msg)) => assert!(msg.contains("eagle")),
        Err(e) => panic!("expected simulated OOM, got error {e}"),
        Ok(_) => panic!("expected simulated OOM for eagle tree b16"),
    }
}

/// Perplexity ordering (paper Tables 1/3): W16A16 <= W4A16 <= W4A4.
fn check_perplexity_ordering(sess: &Session) {
    let rows = sess.store.root.join("eval").join("text_ppl.json");
    let p16 = evalsuite::perplexity(sess, "s", "atom", "w16a16", &rows).expect("ppl");
    let p4a16 = evalsuite::perplexity(sess, "s", "atom", "w4a16", &rows).expect("ppl");
    let p4a4 = evalsuite::perplexity(sess, "s", "atom", "w4a4", &rows).expect("ppl");
    assert!(p16 > 1.0 && p16 < 64.0, "fp ppl implausible: {p16}");
    assert!(
        p4a16 >= p16 * 0.98,
        "w4a16 ppl should not beat fp: {p4a16} vs {p16}"
    );
    assert!(
        p4a4 >= p4a16 * 0.98,
        "w4a4 ppl should not beat w4a16: {p4a4} vs {p4a16}"
    );
}
