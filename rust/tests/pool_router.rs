//! Engine-pool conformance suite (protocol v1.3): mock replica pools
//! served through the real frontend — conn threads -> router thread ->
//! replica threads — plus property tests on the routing layer.
//!
//! Everything here is session-free: replicas are
//! `coordinator::mock::EchoEngine` instances living on their own
//! threads exactly like real engine workers (built in-thread, id space
//! partitioned, status published), so the full v1.3 surface — routed
//! admission (incl. prefix-affinity placement), owner-scoped cancel,
//! drain/undrain, per-class shedding, pooled stats with the
//! prefix-cache counters — runs in CI without artifacts.

use std::net::TcpListener;
use std::sync::{mpsc, Arc};
use std::thread;

use qspec::config::{parse_per_class_slo, RouteKind, SloConfig};
use qspec::coordinator::{BatchCore, EchoEngine, Engine};
use qspec::costmodel::{twins::Twin, CostModel};
use qspec::kvcache::SlotManager;
use qspec::server::{self, Inbound, ReplicaHandle, ReplicaStatus, RouterCore};
use qspec::util::json::Json;
use qspec::util::prng::Pcg32;

mod common;
use common::{mock_tokenizer, Client};

// ---------------------------------------------------------------------------
// pool harness: real conn threads + router thread + mock replica threads
// ---------------------------------------------------------------------------

/// One mock replica's shape.
#[derive(Clone, Copy)]
struct ReplicaSpec {
    batch: usize,
    delay_ms: u64,
    acceptance: Option<f64>,
    /// KV block size for the paged cache. The default (16) equals the
    /// mock prefill clamp, so prompts can never span a full block and
    /// the prefix cache stays inert; the affinity scenario shrinks it
    /// to make repeat prefixes actually hit.
    kv_block: usize,
}

impl ReplicaSpec {
    fn new(batch: usize, delay_ms: u64) -> Self {
        ReplicaSpec { batch, delay_ms, acceptance: None, kv_block: 16 }
    }
}

/// What a replica saw, reported when its loop exits.
struct ReplicaReport {
    replica: usize,
    requests_done: u64,
    cancelled: u64,
}

/// Bind an ephemeral port and stand up the full v1.3 serving stack
/// over mock replicas: exactly `n_conns` connections are served, then
/// the stack winds down and each replica posts its [`ReplicaReport`].
fn start_pool(
    specs: &[ReplicaSpec],
    route: RouteKind,
    slo: SloConfig,
    n_conns: usize,
) -> (String, mpsc::Receiver<ReplicaReport>, Vec<thread::JoinHandle<()>>) {
    let n = specs.len();
    let (report_tx, report_rx) = mpsc::channel::<ReplicaReport>();
    let mut replicas = Vec::new();
    let mut joins = Vec::new();
    for (k, spec) in specs.iter().copied().enumerate() {
        let status = Arc::new(ReplicaStatus::new());
        let (tx, rx) = mpsc::channel::<Inbound>();
        let st = status.clone();
        let rep = report_tx.clone();
        joins.push(thread::spawn(move || {
            // engines are built on their worker thread, like real
            // (non-Send) replicas
            let tok = mock_tokenizer();
            let mut engine = EchoEngine::new(spec.batch, 512, spec.delay_ms);
            if let Some(a) = spec.acceptance {
                engine = engine.with_acceptance(a);
            }
            engine.core_mut().slots.configure_paging(spec.kv_block, true);
            engine.core_mut().set_id_space(k as u64, n as u64);
            server::pool::replica_loop(&rx, &tok, &mut engine, &st).expect("replica loop");
            let m = engine.metrics();
            let _ = rep.send(ReplicaReport {
                replica: k,
                requests_done: m.requests_done,
                cancelled: m.cancelled,
            });
        }));
        replicas.push(ReplicaHandle { tx, status, label: "mock".into() });
    }
    drop(report_tx);

    let statuses: Vec<Arc<ReplicaStatus>> = replicas.iter().map(|r| r.status.clone()).collect();
    let mut core = RouterCore::new(statuses, route, slo);
    let (rtx, rrx) = mpsc::channel::<Inbound>();
    joins.push(thread::spawn(move || {
        server::pool::router_loop(&rrx, &mut core, &replicas).expect("router loop");
    }));

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    joins.push(thread::spawn(move || {
        for conn in 0..n_conns as u64 {
            let (stream, _) = match listener.accept() {
                Ok(x) => x,
                Err(_) => return,
            };
            let rtx = rtx.clone();
            thread::spawn(move || server::conn_thread(stream, conn + 1, rtx, 16, 512));
        }
    }));
    (addr, report_rx, joins)
}

fn finish(report_rx: mpsc::Receiver<ReplicaReport>, joins: Vec<thread::JoinHandle<()>>) -> Vec<ReplicaReport> {
    let mut reports: Vec<ReplicaReport> = report_rx.iter().collect();
    for j in joins {
        j.join().expect("pool thread");
    }
    reports.sort_by_key(|r| r.replica);
    reports
}

fn reason(j: &Json) -> &str {
    j.get("finish_reason").unwrap().as_str().unwrap()
}

// ---------------------------------------------------------------------------
// acceptance scenario: least_loaded spread + owner-scoped cancel
// ---------------------------------------------------------------------------

/// The ISSUE's acceptance scenario: a pool of 2 mock replicas serves
/// concurrent streaming requests over TCP under `least_loaded` — the
/// two requests land on distinct replicas (provable from the
/// partitioned id space), cancel reaches the owning replica and frees
/// its slot, and the pooled stats reflect both replicas.
#[test]
fn pool_spreads_concurrent_streams_and_cancels_on_the_owner() {
    let specs = [ReplicaSpec::new(2, 3), ReplicaSpec::new(2, 3)];
    let (addr, report_rx, joins) =
        start_pool(&specs, RouteKind::LeastLoaded, SloConfig::default(), 1);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        c.send(r#"{"op":"generate","prompt":"hi","max_tokens":400,"stream":true}"#);
        let id_a = c.first_new_delta_id(&[]);
        c.send(r#"{"op":"generate","prompt":"yo","max_tokens":400,"stream":true}"#);
        let id_b = c.first_new_delta_id(&[id_a]);
        // distinct replicas: the id space is partitioned, so id mod
        // pool names the owner
        assert_ne!(id_a % 2, id_b % 2, "least_loaded must spread the two streams");
        // cancel both; each cancel must reach its owning replica
        for id in [id_a, id_b] {
            c.send(&format!(r#"{{"op":"cancel","id":{id}}}"#));
            let (term, _) = c.recv_until(|j| {
                j.get("done").is_some() && j.get("id").unwrap().as_i64() == Some(id)
            });
            assert_eq!(reason(&term), "cancelled");
            let (ack, _) = c.recv_until(|j| j.get("cancelled").is_some());
            assert_eq!(ack.get("cancelled").unwrap().as_i64(), Some(id));
        }
        // both slots freed: pooled stats report an idle pool
        c.send(r#"{"op":"stats"}"#);
        let stats = c.recv();
        assert_eq!(stats.get("active").unwrap().as_i64(), Some(0), "slots not freed");
        assert_eq!(stats.get("queue_depth").unwrap().as_i64(), Some(0));
        assert_eq!(stats.get("cancelled").unwrap().as_i64(), Some(2));
        assert_eq!(stats.get("route").unwrap().as_str(), Some("least_loaded"));
        assert_eq!(stats.get("replicas").unwrap().as_arr().unwrap().len(), 2);
    });
    client.join().unwrap();
    let reports = finish(report_rx, joins);
    // ... and the engine-side truth agrees: one cancel on each replica
    assert_eq!(reports.len(), 2);
    for r in &reports {
        assert_eq!(r.cancelled, 1, "replica {} must cancel exactly its own", r.replica);
    }
}

// ---------------------------------------------------------------------------
// drain lifecycle
// ---------------------------------------------------------------------------

#[test]
fn drain_stops_admission_while_queued_and_inflight_work_completes() {
    // batch 1 so a request can be queued behind the in-flight one
    let specs = [ReplicaSpec::new(1, 3), ReplicaSpec::new(1, 3)];
    let (addr, report_rx, joins) =
        start_pool(&specs, RouteKind::RoundRobin, SloConfig::default(), 1);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // A -> replica 0 (round robin from 0), long enough to stay
        // in flight across the drain
        c.send(r#"{"op":"generate","prompt":"hi","max_tokens":40,"stream":true}"#);
        let id_a = c.first_new_delta_id(&[]);
        assert_eq!(id_a % 2, 0);
        // B -> replica 1; C -> replica 0, queued behind A (batch 1)
        c.send(r#"{"prompt":"yo","max_tokens":2}"#);
        c.send(r#"{"prompt":"ab","max_tokens":2}"#);
        // drain replica 0 while A runs and C queues on it (keep every
        // interleaved frame: B may finish at any point)
        c.send(r#"{"op":"drain","replica":0}"#);
        let (ack, mut frames) = c.recv_until(|j| j.get("draining").is_some());
        assert_eq!(ack.get("replica").unwrap().as_i64(), Some(0));
        assert_eq!(ack.get("draining"), Some(&Json::Bool(true)));
        // drained replicas are visible in stats
        c.send(r#"{"op":"stats"}"#);
        let (stats, skipped) = c.recv_until(|j| j.get("replicas").is_some());
        frames.extend(skipped);
        let reps = stats.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps[0].get("draining"), Some(&Json::Bool(true)));
        assert_eq!(reps[1].get("draining"), Some(&Json::Bool(false)));
        // new work now avoids replica 0: D and E both land on 1
        c.send(r#"{"prompt":"cd","max_tokens":2}"#);
        c.send(r#"{"prompt":"ef","max_tokens":2}"#);
        // collect every outstanding terminal: A (in-flight through the
        // drain), B, C (queued on the drained replica), D, E
        while frames.iter().filter(|j| j.get("finish_reason").is_some()).count() < 5 {
            let (j, skipped) = c.recv_until(|j| j.get("finish_reason").is_some());
            frames.extend(skipped);
            frames.push(j);
        }
        let terminals: Vec<&Json> =
            frames.iter().filter(|j| j.get("finish_reason").is_some()).collect();
        let id_of = |j: &Json| j.get("id").unwrap().as_i64().unwrap();
        // A survived the drain and ran to completion on replica 0
        let a = terminals.iter().find(|j| id_of(j) == id_a).expect("A terminal");
        assert_eq!(reason(a), "length");
        assert_eq!(a.get("tokens").unwrap().as_i64(), Some(40));
        // C was already queued on replica 0: the drain let it finish
        assert!(
            terminals.iter().any(|j| id_of(j) != id_a && id_of(j) % 2 == 0),
            "the request queued on the drained replica must complete"
        );
        // D and E (sent after the drain) avoided replica 0
        let post_drain_on_r1 =
            terminals.iter().filter(|j| id_of(j) % 2 == 1).count();
        assert_eq!(post_drain_on_r1, 3, "B, D and E all belong to replica 1");
        // undrain restores admission to replica 0
        c.send(r#"{"op":"undrain","replica":0}"#);
        let (ack, _) = c.recv_until(|j| j.get("draining").is_some());
        assert_eq!(ack.get("draining"), Some(&Json::Bool(false)));
        // out-of-range drains answer bad_request
        c.send(r#"{"op":"drain","replica":9}"#);
        let (err, _) = c.recv_until(|j| j.get("error").is_some());
        let err = err.get("error").unwrap();
        assert_eq!(err.get("code").unwrap().as_str(), Some("bad_request"));
        assert!(err.get("message").unwrap().as_str().unwrap().contains("out of range"));
    });
    client.join().unwrap();
    let reports = finish(report_rx, joins);
    assert_eq!(reports[0].requests_done, 2, "replica 0 finished A and C");
    assert_eq!(reports[1].requests_done, 3, "replica 1 finished B, D, E");
}

// ---------------------------------------------------------------------------
// pooled stats: per-replica entries + aggregates
// ---------------------------------------------------------------------------

#[test]
fn pooled_stats_merge_per_replica_identity_and_acceptance() {
    // heterogeneous pool: replica 0 "drafts" (simulated acceptance
    // 0.75), replica 1 is a plain AR echo
    let mut spec0 = ReplicaSpec::new(2, 0);
    spec0.acceptance = Some(0.75);
    let specs = [spec0, ReplicaSpec::new(2, 0)];
    let (addr, report_rx, joins) =
        start_pool(&specs, RouteKind::RoundRobin, SloConfig::default(), 1);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // two requests, one per replica (round robin)
        c.send(r#"{"prompt":"hi","max_tokens":4}"#);
        c.send(r#"{"prompt":"yo","max_tokens":4}"#);
        let mut done = 0;
        while done < 2 {
            let (_, skipped) = c.recv_until(|j| j.get("finish_reason").is_some());
            assert!(skipped.is_empty());
            done += 1;
        }
        c.send(r#"{"op":"stats"}"#);
        let stats = c.recv();
        // pooled top level keeps the v1.1 fields as aggregates
        assert_eq!(stats.get("requests_done").unwrap().as_i64(), Some(2));
        assert_eq!(stats.get("tokens_out").unwrap().as_i64(), Some(8));
        assert_eq!(stats.get("slots").unwrap().as_i64(), Some(4));
        assert_eq!(stats.get("engine").unwrap().as_str(), Some("mock"));
        assert_eq!(stats.get("sched").unwrap().as_str(), Some("fcfs"));
        assert_eq!(stats.get("route").unwrap().as_str(), Some("round_robin"));
        // pooled acceptance comes from the summed counters — only
        // replica 0 drafts, so the pool measures its 75%
        let acc = stats.get("acceptance_rate").unwrap().as_f64().expect("pool drafted");
        assert!((acc - 0.75).abs() < 1e-9, "pooled acceptance {acc}");
        // per-replica entries carry their own identity and signals
        let reps = stats.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(reps.len(), 2);
        for (k, r) in reps.iter().enumerate() {
            assert_eq!(r.get("replica").unwrap().as_i64(), Some(k as i64));
            assert_eq!(r.get("draining"), Some(&Json::Bool(false)));
            assert_eq!(r.get("engine").unwrap().as_str(), Some("mock"));
            assert_eq!(r.get("requests_done").unwrap().as_i64(), Some(1));
        }
        let acc0 = reps[0].get("acceptance_rate").unwrap().as_f64().expect("drafter");
        assert!((acc0 - 0.75).abs() < 1e-9);
        assert_eq!(reps[1].get("acceptance_rate"), Some(&Json::Null), "AR echo: null");
    });
    client.join().unwrap();
    finish(report_rx, joins);
}

// ---------------------------------------------------------------------------
// prefix-affinity routing + prefix-cache stats, end to end
// ---------------------------------------------------------------------------

/// The v1.3 acceptance scenario: under `prefix_affinity`, the second
/// turn of a session lands on the replica already holding its prefix
/// in the paged KV cache — even when that replica is busier — while
/// unrelated prompts fall back to least-loaded, and the pooled stats
/// report the cache hits.
#[test]
fn prefix_affinity_lands_follow_up_turns_on_the_caching_replica() {
    // kv_block 4 so the 16-token prefill clamp spans multiple blocks
    // and a shared prefix can actually be served from cache
    let mut spec = ReplicaSpec::new(2, 3);
    spec.kv_block = 4;
    let specs = [spec, spec];
    let (addr, report_rx, joins) =
        start_pool(&specs, RouteKind::PrefixAffinity, SloConfig::default(), 1);
    let client = thread::spawn(move || {
        let sys = "you are a helpful bot. "; // > 16 chars: spans the clamp
        let mut c = Client::connect(&addr);
        // turn 1 of the session: cold pool, affinity nowhere — the
        // least-loaded/index fallback places it (deterministically on
        // replica 0, but derive the owner from the id to stay robust)
        c.send(&format!(r#"{{"prompt":"{sys}q one","max_tokens":2}}"#));
        let t1 = c.recv();
        let k1 = (t1.get("id").unwrap().as_i64().unwrap() % 2) as u64;
        // a long stream sharing the prefix sticks to the same replica
        // and keeps it busy for the rest of the scenario
        c.send(&format!(
            r#"{{"op":"generate","prompt":"{sys}q pin","max_tokens":400,"stream":true}}"#
        ));
        let pin_id = c.first_new_delta_id(&[]);
        assert_eq!(pin_id % 2, k1 as i64, "shared prefix must follow the session");
        // turn 2: the other replica is idle, but affinity must beat
        // the load difference and land on the caching replica
        c.send(&format!(r#"{{"prompt":"{sys}q two","max_tokens":2}}"#));
        let (t2, _) = c.recv_until(|j| {
            j.get("finish_reason").is_some() && j.get("id").unwrap().as_i64() != Some(pin_id)
        });
        assert_eq!(
            t2.get("id").unwrap().as_i64().unwrap() % 2,
            k1 as i64,
            "second turn must land on the replica holding its prefix"
        );
        // an unrelated prompt has no affinity anywhere: least-loaded
        // fallback routes it away from the busy caching replica
        c.send(r#"{"prompt":"zzzz zzzz zzzz zzzz","max_tokens":2}"#);
        let (t3, _) = c.recv_until(|j| {
            j.get("finish_reason").is_some() && j.get("id").unwrap().as_i64() != Some(pin_id)
        });
        assert_ne!(
            t3.get("id").unwrap().as_i64().unwrap() % 2,
            k1 as i64,
            "no-affinity prompt must fall back least-loaded"
        );
        c.send(&format!(r#"{{"op":"cancel","id":{pin_id}}}"#));
        let (_, _) = c.recv_until(|j| j.get("cancelled").is_some());
        // pooled v1.3 stats: 4 admissions ran a prefix lookup; the pin
        // and turn 2 each reused 3 of their 4 blocks (12 tokens — the
        // last prompt block always prefills to yield first-token
        // logits), so 24 hit tokens and a 6.0 pooled hit rate
        c.send(r#"{"op":"stats"}"#);
        let (stats, _) = c.recv_until(|j| j.get("replicas").is_some());
        assert_eq!(stats.get("route").unwrap().as_str(), Some("prefix_affinity"));
        assert_eq!(stats.get("prefix_queries").unwrap().as_i64(), Some(4));
        assert_eq!(stats.get("prefix_hit_tokens").unwrap().as_i64(), Some(24));
        assert_eq!(stats.get("prefix_hit_rate").unwrap().as_f64(), Some(6.0));
        // the hits all live on the session's replica
        let reps = stats.get("replicas").unwrap().as_arr().unwrap();
        let hits = |k: usize| reps[k].get("prefix_hit_tokens").unwrap().as_i64().unwrap();
        assert_eq!(hits(k1 as usize), 24);
        assert_eq!(hits(1 - k1 as usize), 0);
    });
    client.join().unwrap();
    finish(report_rx, joins);
}

// ---------------------------------------------------------------------------
// per-class shedding at the router, over TCP
// ---------------------------------------------------------------------------

#[test]
fn router_sheds_by_class_table_and_reports_the_class() {
    // depth cap 1 per class-0 request; classes 1+ exempt
    let slo = SloConfig {
        per_class: Some(parse_per_class_slo("1:-,-,-,-").unwrap()),
        retry_after_ms: 333,
        ..SloConfig::default()
    };
    let specs = [ReplicaSpec::new(1, 3), ReplicaSpec::new(1, 3)];
    let (addr, report_rx, joins) = start_pool(&specs, RouteKind::LeastLoaded, slo, 1);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // pin both single-slot replicas with long streams
        c.send(r#"{"op":"generate","prompt":"hi","max_tokens":300,"stream":true}"#);
        let id_a = c.first_new_delta_id(&[]);
        c.send(r#"{"op":"generate","prompt":"yo","max_tokens":300,"stream":true}"#);
        let id_b = c.first_new_delta_id(&[id_a]);
        // queue one request per replica: pool depth reaches 2 >= 1 x 2
        c.send(r#"{"prompt":"ab","max_tokens":2}"#);
        c.send(r#"{"prompt":"cd","max_tokens":2}"#);
        // class 0 is now past its table threshold: shed, frame names it
        c.send(r#"{"op":"generate","prompt":"no","max_tokens":2,"priority":0}"#);
        let (ov, _) = c.recv_until(|j| j.get("error").is_some());
        let err = ov.get("error").unwrap();
        assert_eq!(err.get("code").unwrap().as_str(), Some("overloaded"));
        assert_eq!(err.get("class").unwrap().as_i64(), Some(0), "tripped class reported");
        assert_eq!(err.get("retry_after_ms").unwrap().as_i64(), Some(333));
        assert!(err.get("message").unwrap().as_str().unwrap().contains("pool queue depth"));
        // the default class (1) is exempt in this table: still admitted
        c.send(r#"{"prompt":"ef","max_tokens":2}"#);
        // unpin the slots; every admitted request completes
        c.send(&format!(r#"{{"op":"cancel","id":{id_a}}}"#));
        c.send(&format!(r#"{{"op":"cancel","id":{id_b}}}"#));
        let mut terminals = 0;
        let mut frames = Vec::new();
        while terminals < 5 {
            let (j, skipped) = c.recv_until(|j| j.get("finish_reason").is_some());
            frames.extend(skipped);
            frames.push(j);
            terminals = frames.iter().filter(|j| j.get("finish_reason").is_some()).count();
        }
        let cancelled =
            frames.iter().filter(|j| j.get("finish_reason").is_some() && reason(j) == "cancelled").count();
        assert_eq!(cancelled, 2, "only the two pinned streams were cancelled");
    });
    client.join().unwrap();
    let reports = finish(report_rx, joins);
    let done: u64 = reports.iter().map(|r| r.requests_done).sum();
    assert_eq!(done, 3, "the shed request never reached a replica");
}

// ---------------------------------------------------------------------------
// legacy compatibility: a single-replica pool is the v1.1 server
// ---------------------------------------------------------------------------

#[test]
fn single_replica_pool_keeps_v11_surface() {
    let specs = [ReplicaSpec::new(2, 0)];
    let (addr, report_rx, joins) =
        start_pool(&specs, RouteKind::RoundRobin, SloConfig::default(), 1);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // legacy bare-prompt line: same single result frame, same
        // fields, ids dense from 0 (stride 1)
        c.send(r#"{"prompt":"x","max_tokens":3}"#);
        let r = c.recv();
        assert_eq!(r.get("id").unwrap().as_i64(), Some(0));
        assert_eq!(r.get("text").unwrap().as_str(), Some("hij"));
        assert_eq!(reason(&r), "length");
        for key in ["latency_ms", "queue_ms", "tokens"] {
            assert!(r.get(key).is_some(), "v1 result field {key}");
        }
        c.send(r#"{"prompt":"x","max_tokens":3}"#);
        let r = c.recv();
        assert_eq!(r.get("id").unwrap().as_i64(), Some(1), "ids stay dense");
        // v1.1 error surface: foreign/unknown cancel answers not_found
        c.send(r#"{"op":"cancel","id":99}"#);
        let err = c.recv();
        assert_eq!(err.get("error").unwrap().get("code").unwrap().as_str(), Some("not_found"));
        // v1.1 stats fields all present at the top level
        c.send(r#"{"op":"stats"}"#);
        let stats = c.recv();
        for key in [
            "engine", "sched", "queue_depth", "queue_depth_by_priority", "oldest_queued_ms",
            "active", "slots", "requests_done", "cancelled", "shed", "deadline_expired",
            "tokens_out", "acceptance_rate", "wall_tok_s", "virt_tok_s", "queue_p50_ms",
            "queue_p99_ms", "latency_p50_ms", "latency_p99_ms",
        ] {
            assert!(stats.get(key).is_some(), "v1.1 stats field {key}");
        }
        assert_eq!(stats.get("engine").unwrap().as_str(), Some("mock"));
        assert_eq!(stats.get("requests_done").unwrap().as_i64(), Some(2));
        // draining the only replica sheds every new generate
        c.send(r#"{"op":"drain","replica":0}"#);
        let ack = c.recv();
        assert_eq!(ack.get("draining"), Some(&Json::Bool(true)));
        c.send(r#"{"prompt":"x","max_tokens":3}"#);
        let err = c.recv();
        let err = err.get("error").unwrap();
        assert_eq!(err.get("code").unwrap().as_str(), Some("overloaded"));
        assert!(err.get("message").unwrap().as_str().unwrap().contains("draining"));
        c.send(r#"{"op":"undrain","replica":0}"#);
        let ack = c.recv();
        assert_eq!(ack.get("draining"), Some(&Json::Bool(false)));
        c.send(r#"{"prompt":"x","max_tokens":3}"#);
        assert_eq!(reason(&c.recv()), "length");
    });
    client.join().unwrap();
    let reports = finish(report_rx, joins);
    assert_eq!(reports[0].requests_done, 3);
}

// ---------------------------------------------------------------------------
// routing property tests
// ---------------------------------------------------------------------------

fn statuses_with_loads(loads: &[(usize, usize, usize)]) -> Vec<Arc<ReplicaStatus>> {
    use std::sync::atomic::Ordering;
    loads
        .iter()
        .map(|&(q, a, p)| {
            let st = ReplicaStatus::new();
            st.queue_depth.store(q, Ordering::Relaxed);
            st.active.store(a, Ordering::Relaxed);
            st.pending.store(p, Ordering::Relaxed);
            Arc::new(st)
        })
        .collect()
}

/// least_loaded never picks a replica with a strictly higher live load
/// than some other candidate — under arbitrary load vectors.
#[test]
fn least_loaded_never_picks_a_strictly_deeper_replica() {
    let mut rng = Pcg32::seeded(0xF00D);
    for _ in 0..300 {
        let n = rng.range_inclusive(2, 6) as usize;
        let loads: Vec<(usize, usize, usize)> = (0..n)
            .map(|_| {
                (
                    rng.range_inclusive(0, 12) as usize,
                    rng.range_inclusive(0, 4) as usize,
                    rng.range_inclusive(0, 3) as usize,
                )
            })
            .collect();
        let mut core = RouterCore::new(
            statuses_with_loads(&loads),
            RouteKind::LeastLoaded,
            SloConfig::default(),
        );
        let picked = core.route(1).expect("no SLO: always routable");
        let load = |k: usize| {
            let (q, a, p) = loads[k];
            q + a + p
        };
        for k in 0..n {
            assert!(
                load(picked) <= load(k),
                "picked replica {picked} (load {}) over {k} (load {}) — loads {loads:?}",
                load(picked),
                load(k)
            );
        }
    }
}

/// Ids assigned by stride-partitioned BatchCores always map back to
/// their replica through the router's owner arithmetic — under random
/// interleavings of submissions, so a cancel routed by owner_of can
/// never land on a foreign replica.
#[test]
fn cancel_owner_arithmetic_matches_assignment() {
    let mut rng = Pcg32::seeded(42);
    for n in 1..=5usize {
        let mut cores: Vec<BatchCore> = (0..n)
            .map(|k| {
                let mut c = BatchCore::new(
                    SlotManager::new(2, 64, 16),
                    CostModel::new(Twin::lookup("llama2-7b")),
                );
                c.set_id_space(k as u64, n as u64);
                c
            })
            .collect();
        let statuses = (0..n).map(|_| Arc::new(ReplicaStatus::new())).collect();
        let router = RouterCore::new(statuses, RouteKind::RoundRobin, SloConfig::default());
        for _ in 0..200 {
            let k = rng.range_inclusive(0, (n - 1) as u32) as usize;
            let id = cores[k].submit(vec![1, 2], 4);
            assert_eq!(router.owner_of(id), k, "id {id} must route back to replica {k}");
        }
    }
}

/// Draining a replica in a live RouterCore: nothing routes to it until
/// undrained, whatever the policy.
#[test]
fn drain_property_holds_for_every_policy() {
    for route in RouteKind::ALL {
        let statuses = statuses_with_loads(&[(0, 0, 0), (9, 9, 9), (1, 1, 0)]);
        let mut core = RouterCore::new(statuses, route, SloConfig::default());
        core.set_draining(0, true).unwrap();
        for _ in 0..10 {
            let k = core.route(1).unwrap();
            assert_ne!(k, 0, "{}: routed to a draining replica", core.route_name());
        }
        core.set_draining(0, false).unwrap();
        // replica 0 is routable again (least_loaded picks it at once;
        // the others reach it within a cycle)
        let picks: Vec<usize> = (0..6).map(|_| core.route(1).unwrap()).collect();
        assert!(picks.contains(&0), "{}: undrained replica never picked", core.route_name());
    }
}
