//! Observability suite (protocol v1.5): properties of the tracing
//! ring under concurrency, plus wire-level scenarios for the metrics
//! op and the flight recorder — a request's spans must reconstruct
//! end-to-end across the router and a TCP worker, and a worker whose
//! engine panics must leave a parseable flight dump behind.
//!
//! Everything here runs artifact-free: the mock echo engine over real
//! sockets, same as the transport suite.

use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use qspec::config::{RouteKind, SloConfig};
use qspec::coordinator::mock::FailureMode;
use qspec::coordinator::EchoEngine;
use qspec::obs::{EventKind, Tracer};
use qspec::server::transport::{self, RemoteOpts, WorkerOpts};
use qspec::server::{self, Inbound, PoolLifecycle, RouterCore};
use qspec::util::json::Json;
use qspec::util::prng::Pcg32;

mod common;
use common::{mock_tokenizer, Client};

// ---------------------------------------------------------------------------
// ring properties
// ---------------------------------------------------------------------------

#[test]
fn ring_never_exceeds_bound_under_concurrent_writers() {
    let t = Arc::new(Tracer::new(64));
    let mut handles = Vec::new();
    for _ in 0..4 {
        let t = t.clone();
        handles.push(thread::spawn(move || {
            for _ in 0..5000 {
                t.instant("tick", None, 0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(t.len(), 64, "ring fills to its bound exactly");
    assert_eq!(t.dropped(), 4 * 5000 - 64, "every eviction is counted");
}

#[test]
fn disabled_tracing_emits_nothing_from_any_thread() {
    let t = Arc::new(Tracer::disabled(256));
    let mut handles = Vec::new();
    for _ in 0..4 {
        let t = t.clone();
        handles.push(thread::spawn(move || {
            for _ in 0..100 {
                t.instant("ev", Some(1), 2);
                t.instant_with("ev2", None, 0, || unreachable!("lazy detail must not run"));
                let _g = t.scope("quiet");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(t.is_empty());
    assert_eq!(t.dropped(), 0);
}

/// Spans opened and closed by many threads interleave in the shared
/// ring, but per emitting thread the Start/End sequence must replay as
/// a well-formed nesting stack (every End matches the most recent
/// unclosed Start of that thread).
#[test]
fn spans_nest_well_formed_under_random_interleavings() {
    const NAMES: [&str; 4] = ["phase.prefill", "phase.draft", "phase.verify", "phase.commit"];
    let t = Arc::new(Tracer::new(1 << 14));
    let mut handles = Vec::new();
    for seed in 0..4u64 {
        let t = t.clone();
        handles.push(thread::spawn(move || {
            let mut rng = Pcg32::seeded(0xC0FFEE ^ seed);
            let mut open = Vec::new();
            for i in 0..200 {
                match rng.below(3) {
                    0 if open.len() < 5 => {
                        let name = NAMES[rng.below(NAMES.len() as u32) as usize];
                        open.push(t.scope_req(name, Some(i as u64), i as u64));
                    }
                    1 => {
                        open.pop(); // closes the innermost span, if any
                    }
                    _ => t.instant("tick", None, 0),
                }
            }
            drop(open); // close whatever is still open, innermost last
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(t.dropped(), 0, "capacity sized so the property sees every event");
    let mut stacks: std::collections::HashMap<u64, Vec<u64>> = std::collections::HashMap::new();
    let mut total_spans = 0u64;
    for ev in t.snapshot() {
        let stack = stacks.entry(ev.tid).or_default();
        match ev.kind {
            EventKind::Start => {
                total_spans += 1;
                stack.push(ev.span);
            }
            EventKind::End => {
                assert_eq!(
                    stack.pop(),
                    Some(ev.span),
                    "End must close this thread's most recent unclosed Start"
                );
            }
            EventKind::Instant => assert_eq!(ev.span, 0),
        }
    }
    for (tid, stack) in stacks {
        assert!(stack.is_empty(), "thread {tid} left spans unclosed");
    }
    assert!(total_spans > 0, "the walk must actually open spans");
}

// ---------------------------------------------------------------------------
// TCP harness (mock worker + router + frontend, as in the transport suite)
// ---------------------------------------------------------------------------

fn free_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("probe bind");
    let addr = format!("127.0.0.1:{}", l.local_addr().unwrap().port());
    drop(l);
    addr
}

fn wait_listening(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if TcpStream::connect(addr).is_ok() {
            return;
        }
        assert!(Instant::now() < deadline, "worker at {addr} never came up");
        thread::sleep(Duration::from_millis(10));
    }
}

fn spawn_mock_worker(addr: &str, failure: Option<FailureMode>, flight_dir: Option<PathBuf>) {
    let addr = addr.to_string();
    thread::spawn(move || {
        let tok = mock_tokenizer();
        let mut engine = EchoEngine::new(8, 512, 0);
        if let Some(mode) = failure {
            engine = engine.with_failure(mode);
        }
        let opts = WorkerOpts { flight_dir, ..WorkerOpts::default() };
        let _ = transport::serve_worker_with_opts(&addr, &tok, &mut engine, opts);
    });
}

/// One remote mock replica behind the real router + frontend; returns
/// the frontend address.
fn start_router(worker_addr: &str) -> String {
    wait_listening(worker_addr);
    let (rtx, rrx) = mpsc::channel::<Inbound>();
    let remote = transport::connect_remote(0, 1, worker_addr, rtx.clone(), RemoteOpts::default())
        .expect("worker handshake");
    let statuses = vec![remote.handle.status.clone()];
    let mut slots = vec![Some(remote.handle)];
    let mut core = RouterCore::new(statuses, RouteKind::RoundRobin, SloConfig::default());
    thread::spawn(move || {
        let mut life = PoolLifecycle::new();
        let _ = server::pool::router_loop_dynamic(&rrx, &mut core, &mut slots, &mut life);
    });
    let listener = TcpListener::bind("127.0.0.1:0").expect("frontend bind");
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    thread::spawn(move || {
        let mut conn = 0u64;
        for stream in listener.incoming().flatten() {
            conn += 1;
            let rtx = rtx.clone();
            let c = conn;
            thread::spawn(move || server::conn_thread(stream, c, rtx, 16, 512));
        }
    });
    addr
}

// ---------------------------------------------------------------------------
// wire scenarios
// ---------------------------------------------------------------------------

/// `{"op":"metrics"}` answers Prometheus exposition text, and
/// `{"op":"dump"}` reconstructs one request's timeline across both
/// sides of the wire: the router's ring shows the placement, the
/// worker's ring shows the request lifecycle with the same id.
#[test]
fn metrics_and_dump_reconstruct_a_request_across_router_and_worker() {
    let waddr = free_addr();
    spawn_mock_worker(&waddr, None, None);
    let frontend = start_router(&waddr);
    let mut c = Client::connect(&frontend);

    c.send(r#"{"op":"generate","prompt":"q: traced ?\n","max_tokens":8}"#);
    let (done, _) = c.recv_until(|j| j.get("finish_reason").is_some());
    let id = done.get("id").and_then(Json::as_i64).expect("request id");

    c.send(r#"{"op":"metrics"}"#);
    let (m, _) = c.recv_until(|j| j.get("op").and_then(Json::as_str) == Some("metrics"));
    let body = m.get("body").and_then(Json::as_str).expect("metrics body");
    assert!(body.contains("# TYPE"), "exposition text has TYPE headers");
    assert!(body.contains("qspec_build_info"), "identity series present");
    assert!(body.contains("qspec_requests_done_total 1"), "the generate is counted");
    assert!(body.contains("qspec_replica_queue_depth"), "per-replica series present");

    c.send(r#"{"op":"dump"}"#);
    let (d, _) = c.recv_until(|j| j.get("op").and_then(Json::as_str) == Some("dump"));
    let router_events = d
        .get("router")
        .and_then(|r| r.get("events"))
        .and_then(Json::as_arr)
        .expect("router ring");
    assert!(
        router_events
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("route.assign")),
        "router ring shows the placement"
    );
    let reps = d.get("replicas").and_then(Json::as_arr).expect("replica dumps");
    assert_eq!(reps.len(), 1);
    let ev_named = |name: &str| {
        reps[0]
            .get("events")
            .and_then(Json::as_arr)
            .into_iter()
            .flatten()
            .find(|e| {
                e.get("name").and_then(Json::as_str) == Some(name)
                    && e.get("request").and_then(Json::as_i64) == Some(id)
            })
            .cloned()
    };
    assert!(ev_named("request.submitted").is_some(), "worker ring has the admission");
    assert!(ev_named("request.done").is_some(), "worker ring has the completion");
    // the whole dump frame round-trips through the line protocol
    assert!(Json::parse(&d.to_string()).is_ok());
}

/// A worker whose engine panics mid-session writes a parseable flight
/// dump (and survives to accept the next router session).
#[test]
fn worker_panic_leaves_a_parseable_flight_dump() {
    let dir = std::env::temp_dir()
        .join(format!("qspec-obs-panic-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let waddr = free_addr();
    spawn_mock_worker(&waddr, Some(FailureMode::PanicAfterN(3)), Some(dir.clone()));
    wait_listening(&waddr);

    // drive the worker directly over its own documented wire: hello,
    // then one generate envelope long enough to cross the fault cycle
    let mut w = Client::connect(&waddr);
    w.send(r#"{"hello":{"pool":1,"replica":0}}"#);
    let welcome = w.recv();
    assert!(welcome.get("welcome").is_some(), "handshake completes");
    w.send(
        r#"{"conn":1,"op":{"op":"generate","prompt":"q: g abcd ?\n","max_tokens":64},"tag":1}"#,
    );

    // the panic tears the session down after writing the dump
    let deadline = Instant::now() + Duration::from_secs(10);
    let dump_path = loop {
        let found = std::fs::read_dir(&dir).ok().and_then(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .find(|p| p.file_name().is_some_and(|n| {
                    n.to_string_lossy().starts_with("flight-")
                }))
        });
        if let Some(p) = found {
            break p;
        }
        assert!(Instant::now() < deadline, "no flight dump appeared in {}", dir.display());
        thread::sleep(Duration::from_millis(20));
    };
    let text = std::fs::read_to_string(&dump_path).expect("read dump");
    let dump = Json::parse(text.trim()).expect("flight dump is one JSON object");
    let reason = dump.get("reason").and_then(Json::as_str).expect("reason");
    assert!(reason.starts_with("panic:"), "reason records the panic: {reason}");
    assert!(reason.contains("injected failure"), "panic message rides along");
    assert_eq!(dump.get("engine").and_then(Json::as_str), Some("mock"));
    let events = dump.get("events").and_then(Json::as_arr).expect("events");
    assert!(
        events
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("request.submitted")),
        "the in-flight request's spans are in the dump"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
