//! Shared integration-test scaffolding: the mock-alphabet tokenizer
//! and a blocking line-protocol client, used by both the cross-engine
//! conformance suite (`engine_trait.rs`) and the pool/router suite
//! (`pool_router.rs`) so the wire-level helpers cannot drift apart.
//!
//! Each integration-test binary compiles this module independently and
//! uses a different subset of it, hence the blanket `dead_code` allow.
#![allow(dead_code)]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use qspec::util::json::Json;

/// The mock tokenizer (and its `MOCK_ALPHABET`) live next to
/// `EchoEngine` in the library so the benches share them too.
pub use qspec::coordinator::mock::mock_tokenizer;

/// Blocking line-protocol client.
pub struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: &str) -> Client {
        let w = TcpStream::connect(addr).expect("connect");
        let r = BufReader::new(w.try_clone().expect("clone"));
        Client { w, r }
    }

    pub fn send(&mut self, line: &str) {
        writeln!(self.w, "{line}").expect("send");
    }

    pub fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.r.read_line(&mut line).expect("recv");
        assert!(n > 0, "server closed the connection unexpectedly");
        Json::parse(line.trim()).expect("frame is JSON")
    }

    /// Read frames until `pred` matches one; interleaved frames from
    /// concurrent streams are collected and returned alongside it.
    pub fn recv_until(&mut self, mut pred: impl FnMut(&Json) -> bool) -> (Json, Vec<Json>) {
        let mut skipped = Vec::new();
        loop {
            let j = self.recv();
            if pred(&j) {
                return (j, skipped);
            }
            skipped.push(j);
        }
    }

    /// First delta frame of a freshly sent streaming generate whose id
    /// is not in `known` — the engine-assigned id of that request.
    pub fn first_new_delta_id(&mut self, known: &[i64]) -> i64 {
        let (j, _) = self.recv_until(|j| {
            j.get("delta").is_some()
                && j.get("id").and_then(Json::as_i64).is_some_and(|id| !known.contains(&id))
        });
        j.get("id").unwrap().as_i64().unwrap()
    }

    /// Drive one streaming generate: returns (concatenated delta text,
    /// summed delta token count, terminal frame).
    pub fn stream_generate(&mut self, req_line: &str) -> (String, i64, Json) {
        self.send(req_line);
        let mut text = String::new();
        let mut ntok = 0i64;
        loop {
            let j = self.recv();
            if let Some(err) = j.get("error") {
                panic!("stream errored: {err:?}");
            }
            if j.get("done").is_some() {
                return (text, ntok, j);
            }
            text.push_str(j.get("delta").expect("delta").as_str().unwrap());
            ntok += j.get("tokens").unwrap().as_i64().unwrap();
        }
    }
}
