//! Cross-engine conformance suite + serving-protocol tests.
//!
//! Three layers:
//!
//! * **The conformance battery** ([`conformance`]): one generic
//!   `fn conformance(&mut dyn Engine, ...)` exercising the full engine
//!   contract — admission/completion invariants, streaming deltas,
//!   cancel-queued, cancel-mid-flight (slot verifiably freed), stop
//!   sequences, deadline expiry, stochastic sampling (temperature > 0
//!   completes and replays on the seed whenever the engine does not
//!   advertise `argmax_only`), and the stats-snapshot shape. Every
//!   present and future `EngineKind` must pass the *identical* battery;
//!   [`conformance_kinds`] matches exhaustively on `EngineKind`, so
//!   adding a variant fails this suite at compile time until the new
//!   engine is wired in.
//! * **Session-free server tests** (always run): a mock engine over the
//!   real `BatchCore` runs the battery and is served through the real
//!   TCP frontend (`conn_thread` + `engine_loop`), covering the
//!   protocol surface — streaming round trip, explicit +
//!   disconnect-driven cancellation, stop sequences, QoS
//!   (priority/shedding/deadlines), stochastic sampling served end to
//!   end (v1.6), stats snapshots, legacy one-line requests and precise
//!   error frames.
//! * **Artifact-gated suite** (`make artifacts` first; skips silently
//!   otherwise): every engine kind (QSPEC, AR, EAGLE, HierSpec,
//!   TreeSpec) runs the battery and the same TCP scenarios, plus the
//!   HierSpec and TreeSpec losslessness checks (their committed greedy
//!   output must equal the W4A16 verifier baseline token-for-token)
//!   and the v1.7 stochastic-losslessness sweep (every drafting
//!   engine's committed sampled stream must stay distributed as the AR
//!   verifier's, measured by total variation against an AR baseline
//!   with a self-calibrated noise floor). One #[test] drives the
//!   artifact layer: PJRT client creation is expensive and the handles
//!   are not Send, so a single test owns the session.

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use qspec::config::{EngineKind, SchedKind, ServeConfig, SloConfig};
use qspec::coordinator::{
    build_engine, build_policy, EchoEngine, Engine, FinishReason, GenerationRequest,
    SamplingParams, StepEvent,
};
use qspec::evalsuite;
use qspec::model::{Mode, Tokenizer};
use qspec::runtime::{ArtifactStore, Session};
use qspec::server::{self, Inbound};
use qspec::util::json::Json;

mod common;
use common::{mock_tokenizer, Client};

// ---------------------------------------------------------------------------
// the engine conformance battery
// ---------------------------------------------------------------------------

/// Upper bound on scheduling steps any battery scenario may take.
const STEP_GUARD: usize = 100_000;

/// The full engine contract, exercised against any `&mut dyn Engine`.
/// The engine must be idle at entry and is left idle at exit, so the
/// battery composes with further scenarios (e.g. the TCP layer) on the
/// same instance. Each scenario asserts against metric *deltas*, so
/// the battery is insensitive to what ran before it.
fn conformance(engine: &mut dyn Engine, tok: &Tokenizer, prompts: &[String]) {
    assert!(prompts.len() >= 2, "battery needs at least two prompts");
    assert!(!engine.has_work(), "{}: battery expects an idle engine", engine.name());
    admission_and_completion(engine, tok, prompts);
    streaming_deltas(engine, tok, &prompts[0]);
    cancel_queued(engine, tok, prompts);
    cancel_mid_flight(engine, tok, prompts);
    stop_sequences(engine, tok, &prompts[0]);
    deadline_expiry(engine, tok, &prompts[1]);
    stochastic_sampling(engine, tok, &prompts[0]);
    stats_shape(engine);
    assert!(!engine.has_work(), "{}: battery must leave the engine idle", engine.name());
}

fn greedy(tok: &Tokenizer, prompt: &str, max_tokens: usize) -> GenerationRequest {
    GenerationRequest::greedy(tok.encode_prompt(prompt), max_tokens)
}

/// Step until `done(engine)` holds, collecting every event.
fn step_until(
    engine: &mut dyn Engine,
    out: &mut Vec<StepEvent>,
    mut done: impl FnMut(&dyn Engine, &[StepEvent]) -> bool,
) {
    for _ in 0..STEP_GUARD {
        if done(&*engine, out) {
            return;
        }
        out.extend(engine.step().expect("step"));
    }
    panic!("{}: scenario exceeded {STEP_GUARD} steps", engine.name());
}

/// Admission: ids are engine-assigned, dense and in submission order;
/// every request finishes; the token/latency/queue metrics hold for
/// ANY engine.
fn admission_and_completion(engine: &mut dyn Engine, tok: &Tokenizer, prompts: &[String]) {
    let name = engine.name();
    let before = engine.metrics().clone();
    let virt_before = engine.cost().virtual_ns;
    let n = prompts.len();
    let mut submitted = Vec::new();
    for p in prompts {
        submitted.push(engine.submit_request(greedy(tok, p, 24)));
    }
    for w in submitted.windows(2) {
        assert_eq!(w[1], w[0] + 1, "{name}: ids must be dense and ordered");
    }
    assert!(engine.has_work(), "{name}: submitted work must be visible");

    let mut fins = engine.run_to_completion().expect("run_to_completion");
    assert!(!engine.has_work(), "{name}: work left after completion");
    assert_eq!(fins.len(), n, "{name}: all requests must finish");
    fins.sort_by_key(|f| f.id);
    let ids: Vec<u64> = fins.iter().map(|f| f.id).collect();
    assert_eq!(ids, submitted, "{name}: finished ids != submitted ids");

    let m = engine.metrics();
    assert_eq!(m.requests_done - before.requests_done, n as u64, "{name}");
    // every engine counts exactly the emitted tokens as committed
    assert_eq!(m.committed, m.tokens_out, "{name}");
    let toks: usize = fins.iter().map(|f| f.tokens.len()).sum();
    assert_eq!(toks as u64, m.tokens_out - before.tokens_out, "{name}");
    // the queue-wait histogram sees one admission per request
    assert_eq!(m.queue_wait.count() - before.queue_wait.count(), n as u64, "{name}");
    assert_eq!(m.req_latency.count() - before.req_latency.count(), n as u64, "{name}");
    for f in &fins {
        assert!(f.latency_ns >= f.queue_ns, "{name}: wait > latency");
        assert!(f.prompt_tokens > 0, "{name}: prompt usage missing");
    }
    // the virtual clock advanced (every phase charges it)
    assert!(engine.cost().virtual_ns > virt_before, "{name}");
}

/// Streaming: the per-step deltas concatenate to exactly the terminal
/// token list.
fn streaming_deltas(engine: &mut dyn Engine, tok: &Tokenizer, prompt: &str) {
    let name = engine.name();
    let id = engine.submit_request(greedy(tok, prompt, 8));
    let mut streamed = Vec::new();
    let mut done = None;
    while engine.has_work() {
        for ev in engine.step().expect("step") {
            match ev {
                StepEvent::Delta { id: did, tokens } => {
                    assert_eq!(did, id, "{name}: delta for a foreign id");
                    streamed.extend(tokens);
                }
                StepEvent::Done(f) => done = Some(f),
            }
        }
    }
    let done = done.unwrap_or_else(|| panic!("{name}: no terminal event"));
    assert_eq!(done.id, id, "{name}");
    assert_eq!(streamed, done.tokens, "{name}: delta sum != final tokens");
    assert!(!streamed.is_empty(), "{name}: nothing streamed");
}

/// Cancel-queued: a request still waiting for admission is removed
/// without ever touching a slot; double cancel is a no-op.
fn cancel_queued(engine: &mut dyn Engine, tok: &Tokenizer, prompts: &[String]) {
    let name = engine.name();
    let before = engine.metrics().clone();
    // no step runs between these submits, so everything is queued
    let mut fillers = Vec::new();
    for i in 0..engine.slot_capacity() {
        fillers.push(engine.submit_request(greedy(tok, &prompts[i % prompts.len()], 64)));
    }
    let victim = engine.submit_request(greedy(tok, &prompts[0], 64));
    assert!(engine.queue_depth() > 0, "{name}");

    let f = engine.cancel(victim).unwrap_or_else(|| panic!("{name}: queued not cancellable"));
    assert_eq!(f.finish_reason, FinishReason::Cancelled, "{name}");
    assert!(f.tokens.is_empty(), "{name}: a queued request has no output");
    assert_eq!(engine.active_requests(), 0, "{name}: nothing was admitted");
    assert!(engine.cancel(victim).is_none(), "{name}: double cancel must be a no-op");

    for id in fillers {
        engine.cancel(id).unwrap_or_else(|| panic!("{name}: filler {id} not cancellable"));
    }
    let m = engine.metrics();
    assert_eq!(
        m.cancelled - before.cancelled,
        engine.slot_capacity() as u64 + 1,
        "{name}"
    );
    assert_eq!(m.requests_done, before.requests_done, "{name}: cancelled != done");
    assert!(!engine.has_work(), "{name}: cancels must drain the queue");
}

/// Cancel-mid-flight: a generating request is cancelled, its partial
/// output returned, and its slot (with the KV positions) is verifiably
/// freed — a follow-up request runs to completion in it.
fn cancel_mid_flight(engine: &mut dyn Engine, tok: &Tokenizer, prompts: &[String]) {
    let name = engine.name();
    let before = engine.metrics().clone();
    let victim = engine.submit_request(greedy(tok, &prompts[0], 10_000));
    // step until the victim is generating and has visible output
    let mut events = Vec::new();
    step_until(engine, &mut events, |e, evs| {
        e.active_requests() >= 1
            && evs.iter().any(|ev| matches!(ev, StepEvent::Delta { id, .. } if *id == victim))
    });
    let active_before = engine.active_requests();

    let f = engine.cancel(victim).unwrap_or_else(|| panic!("{name}: active not cancellable"));
    assert_eq!(f.finish_reason, FinishReason::Cancelled, "{name}");
    assert!(!f.tokens.is_empty(), "{name}: partial output must be returned");
    assert_eq!(engine.active_requests(), active_before - 1, "{name}: slot not freed");

    // the freed slot admits and completes a waiter
    let waiter = engine.submit_request(greedy(tok, &prompts[1], 4));
    let fins = engine.run_to_completion().expect("run_to_completion");
    assert_eq!(fins.len(), 1, "{name}");
    assert_eq!(fins[0].id, waiter, "{name}: waiter must run in the freed slot");
    assert_eq!(engine.metrics().cancelled - before.cancelled, 1, "{name}");
    assert!(engine.cancel(victim).is_none(), "{name}: finished ids are not cancellable");
}

/// Stop sequences: a stop derived from the engine's own deterministic
/// greedy output terminates generation with `Stop`, and the matched
/// tokens are trimmed from the output.
fn stop_sequences(engine: &mut dyn Engine, tok: &Tokenizer, prompt: &str) {
    let name = engine.name();
    // reference run: what this engine greedily generates
    engine.submit_request(greedy(tok, prompt, 12));
    let reference = engine.run_to_completion().expect("reference run").remove(0).tokens;
    if reference.len() < 3 {
        // EOS before a 2-token stop could match; nothing to derive
        eprintln!("{name}: output too short for the stop scenario, skipping");
        return;
    }
    let stop: Vec<i32> = reference[1..3].to_vec();
    let mut params = SamplingParams::greedy(12);
    params.stop = vec![stop.clone()];
    let id = engine
        .submit_request(GenerationRequest::new(tok.encode_prompt(prompt), params));
    let fins = engine.run_to_completion().expect("stop run");
    assert_eq!(fins.len(), 1, "{name}");
    assert_eq!(fins[0].id, id, "{name}");
    assert_eq!(fins[0].finish_reason, FinishReason::Stop, "{name}: stop ignored");
    let out = &fins[0].tokens;
    assert!(
        !out.windows(stop.len()).any(|w| w == stop),
        "{name}: matched stop not trimmed: {out:?}"
    );
    assert!(
        reference.starts_with(out),
        "{name}: stop run diverged from the greedy reference: {out:?} vs {reference:?}"
    );
    assert!(out.len() < reference.len(), "{name}: stop did not shorten the output");
}

/// Deadline expiry: a request whose latency budget lapsed while queued
/// terminates with `DeadlineExceeded` at admission, without consuming
/// a slot.
fn deadline_expiry(engine: &mut dyn Engine, tok: &Tokenizer, prompt: &str) {
    let name = engine.name();
    let before = engine.metrics().clone();
    let id = engine.submit_request(greedy(tok, prompt, 8).with_deadline_ms(1));
    thread::sleep(Duration::from_millis(5));
    let events = engine.step().expect("step");
    let f = events
        .into_iter()
        .filter_map(StepEvent::into_done)
        .find(|f| f.id == id)
        .unwrap_or_else(|| panic!("{name}: no terminal event for the expired request"));
    assert_eq!(f.finish_reason, FinishReason::DeadlineExceeded, "{name}");
    assert!(f.tokens.is_empty(), "{name}: expired requests never generate");
    assert_eq!(engine.active_requests(), 0, "{name}: expiry must not burn a slot");
    let m = engine.metrics();
    assert_eq!(m.deadline_expired - before.deadline_expired, 1, "{name}");
    assert_eq!(m.requests_done, before.requests_done, "{name}: expired != done");
    assert!(!engine.has_work(), "{name}");
}

/// Stochastic sampling (v1.6): an engine that does not advertise
/// `argmax_only` must serve `temperature > 0` to completion and replay
/// the identical token stream for an identical `(params, seed)` pair.
/// Engines built from pre-logits artifact sets skip the scenario — the
/// server rejects their sampled requests up front instead.
fn stochastic_sampling(engine: &mut dyn Engine, tok: &Tokenizer, prompt: &str) {
    let name = engine.name();
    if engine.argmax_only() {
        eprintln!("{name}: argmax-only artifact set, skipping the stochastic scenario");
        return;
    }
    let run = |engine: &mut dyn Engine, seed: u64| -> Vec<i32> {
        let params = SamplingParams {
            max_tokens: 12,
            temperature: 0.7,
            seed,
            ..SamplingParams::default()
        };
        let id = engine
            .submit_request(GenerationRequest::new(tok.encode_prompt(prompt), params));
        let mut fins = engine.run_to_completion().expect("sampled run");
        assert_eq!(fins.len(), 1, "{name}");
        let f = fins.remove(0);
        assert_eq!(f.id, id, "{name}");
        assert!(!f.tokens.is_empty(), "{name}: sampled run produced no tokens");
        // a sampled stream may hit EOS before the budget, so only the
        // finish reason's *kind* is pinned, not the length
        assert!(
            matches!(f.finish_reason, FinishReason::Length | FinishReason::Stop),
            "{name}: unexpected finish reason {:?}",
            f.finish_reason
        );
        f.tokens
    };
    let a = run(engine, 42);
    let b = run(engine, 42);
    assert_eq!(a, b, "{name}: same seed must replay the identical stream");
    assert!(!engine.has_work(), "{name}: stochastic scenario left work behind");
}

/// Stats shape: the `/stats` surface serializes for this engine with
/// every required key, and `acceptance_rate` is `null` exactly when
/// the engine never drafted.
fn stats_shape(engine: &mut dyn Engine) {
    let name = engine.name();
    let stats = Json::parse(&server::format_stats(&*engine)).expect("stats frame is JSON");
    assert_eq!(stats.get("engine").unwrap().as_str(), Some(name));
    assert!(stats.get("sched").unwrap().as_str().is_some(), "{name}");
    assert_eq!(stats.get("queue_depth").unwrap().as_i64(), Some(0), "{name}");
    let depths = stats.get("queue_depth_by_priority").unwrap().as_arr().unwrap();
    assert_eq!(depths.len(), 4, "{name}");
    assert_eq!(stats.get("active").unwrap().as_i64(), Some(0), "{name}");
    assert_eq!(
        stats.get("slots").unwrap().as_i64(),
        Some(engine.slot_capacity() as i64),
        "{name}"
    );
    for key in [
        "requests_done", "cancelled", "shed", "deadline_expired", "tokens_out",
        "wall_tok_s", "virt_tok_s", "queue_p50_ms", "queue_p99_ms",
        "latency_p50_ms", "latency_p99_ms", "oldest_queued_ms",
    ] {
        assert!(stats.get(key).and_then(Json::as_f64).is_some(), "{name}: stats {key}");
    }
    let acc = stats.get("acceptance_rate").unwrap();
    if engine.metrics().drafted == 0 {
        assert_eq!(acc, &Json::Null, "{name}: non-drafting engines report null");
    } else {
        assert!(acc.as_f64().is_some(), "{name}: drafting engines report a number");
    }
    // v1.3 prefix-cache counters follow the same raw-counters +
    // null-until-measured convention
    for key in ["prefix_queries", "prefix_hit_tokens"] {
        assert!(stats.get(key).and_then(Json::as_f64).is_some(), "{name}: stats {key}");
    }
    let rate = stats.get("prefix_hit_rate").unwrap();
    if engine.metrics().prefix_queries == 0 {
        assert_eq!(rate, &Json::Null, "{name}: no lookups yet reports null");
    } else {
        assert!(rate.as_f64().is_some(), "{name}: measured hit rate is a number");
    }
}

// ---------------------------------------------------------------------------
// shared harness: TCP frontend around any engine + a tiny line client
// ---------------------------------------------------------------------------

/// Bind an ephemeral port and serve exactly `n_conns` connections
/// through the real `conn_thread`, then drop the inbound sender so
/// `engine_loop` returns once the last connection closes.
fn start_frontend(
    n_conns: usize,
    default_max_tokens: usize,
    cap: usize,
) -> (String, mpsc::Receiver<Inbound>, thread::JoinHandle<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    let (tx, rx) = mpsc::channel::<Inbound>();
    let h = thread::spawn(move || {
        for conn in 0..n_conns as u64 {
            let (stream, _) = match listener.accept() {
                Ok(x) => x,
                Err(_) => return,
            };
            let tx = tx.clone();
            thread::spawn(move || {
                server::conn_thread(stream, conn + 1, tx, default_max_tokens, cap)
            });
        }
    });
    (addr, rx, h)
}

// ---------------------------------------------------------------------------
// session-free layer: mock engine over the real BatchCore
// ---------------------------------------------------------------------------
// (the line-protocol Client and the mock-alphabet tokenizer live in
// tests/common/mod.rs, shared with the pool/router suite)

/// The session-free instantiation of the cross-engine battery: the
/// library's mock echo engine (`coordinator::mock::EchoEngine` —
/// prefill emits token 10, each cycle commits pending + 1, so output
/// text is deterministic "hijk...") must satisfy the exact contract
/// the real engines do. Its `delay_ms` knob widens the race window
/// for the cancellation scenarios below.
#[test]
fn mock_engine_passes_conformance() {
    let tok = mock_tokenizer();
    let prompts: Vec<String> =
        ["hi there", "yo", "abc def", "012 345"].iter().map(|s| s.to_string()).collect();
    let mut engine = EchoEngine::new(2, 512, 0);
    conformance(&mut engine, &tok, &prompts);
}

/// The drafting variant of the mock must pass the identical battery
/// (it commits several tokens per cycle, exercising multi-token
/// deltas and stop matches spanning commits) and report its simulated
/// acceptance through the stats surface.
#[test]
fn mock_engine_with_acceptance_passes_conformance() {
    let tok = mock_tokenizer();
    let prompts: Vec<String> =
        ["hi there", "yo", "abc def", "012 345"].iter().map(|s| s.to_string()).collect();
    let mut engine = EchoEngine::new(2, 512, 0).with_acceptance(0.75);
    conformance(&mut engine, &tok, &prompts);
    let acc = engine.metrics().acceptance_rate_opt().expect("drafting mock");
    assert!((acc - 0.75).abs() < 1e-9);
}

/// The v1.7 tree-drafting mock must pass the identical battery — it
/// runs the real tree container, the real tree acceptance rules and
/// real CoW branch forks per cycle — and its tree counters must show
/// through the metrics surface.
#[test]
fn mock_tree_engine_passes_conformance() {
    let tok = mock_tokenizer();
    let prompts: Vec<String> =
        ["hi there", "yo", "abc def", "012 345"].iter().map(|s| s.to_string()).collect();
    let mut engine = EchoEngine::new(2, 512, 0).with_tree(2, 3).with_acceptance(0.7);
    conformance(&mut engine, &tok, &prompts);
    let m = engine.metrics();
    assert!(m.tree_nodes_drafted > 0, "tree mock never drafted a tree");
    assert!(m.tree_paths > 0, "tree mock never offered a root path");
    assert!(m.accepted_depth.count() > 0, "accepted-depth histogram never recorded");
    assert!(m.drafted >= m.accepted, "acceptance counters inverted");
}

/// v1.6 distribution-losslessness at the engine layer: the drafting
/// mock's committed stream must be distributed exactly as the plain-AR
/// mock's — both equal the toy verifier chain `p` behind
/// `mock_logits`, whatever the (deliberately bad) draft distribution
/// was. Checked empirically on the second committed token over many
/// seeded single-request runs against the *exact* marginal computed
/// from the toy model; a broken accept rule (committing draft samples
/// directly) measures TV ~0.2 here, an order of magnitude above the
/// lossless sampling noise (~0.055 at 4000 trials).
#[test]
fn mock_stochastic_stream_is_distributed_as_the_verifier_chain() {
    use qspec::coordinator::mock::{mock_logits, MOCK_VOCAB};
    use qspec::sampler::softmax_t;

    const TEMP: f32 = 0.8;
    const EOS: i32 = 2;
    const N: u64 = 4000;
    let prompt = vec![1i32, 4, 9];

    // exact marginal of the second committed token, conditioned on the
    // first not being EOS (those runs finish at length 1 and are
    // skipped below): t0 ~ p(.|9), t1 ~ p(.|t0)
    let p0 = softmax_t(&mock_logits(9), TEMP);
    let z = 1.0 - p0[EOS as usize] as f64;
    let mut exact = vec![0f64; MOCK_VOCAB];
    for t0 in 0..MOCK_VOCAB {
        if t0 as i32 == EOS {
            continue;
        }
        let pr = softmax_t(&mock_logits(t0 as i32), TEMP);
        for t1 in 0..MOCK_VOCAB {
            exact[t1] += p0[t0] as f64 / z * pr[t1] as f64;
        }
    }

    let second_token = |acc: Option<f64>, seed: u64| -> Option<i32> {
        let mut e = EchoEngine::new(1, 64, 0);
        if let Some(a) = acc {
            e = e.with_acceptance(a);
        }
        let params = SamplingParams {
            max_tokens: 2,
            temperature: TEMP,
            seed,
            ..SamplingParams::default()
        };
        e.submit_request(GenerationRequest::new(prompt.clone(), params));
        let fins = e.run_to_completion().expect("mock sampled run");
        fins[0].tokens.get(1).copied()
    };

    // acceptance 0.3 puts the largest perturbation on q, so a broken
    // accept rule would show up loudest; None is the plain-AR baseline
    for acc in [None, Some(0.3)] {
        let mut hist = vec![0u64; MOCK_VOCAB];
        let mut n = 0u64;
        for t in 0..N {
            if let Some(t1) = second_token(acc, 123_000 + t) {
                hist[t1 as usize] += 1;
                n += 1;
            }
        }
        assert!(n > N / 2, "too many EOS-terminated runs: {n}/{N}");
        let tv: f64 = (0..MOCK_VOCAB)
            .map(|v| (hist[v] as f64 / n as f64 - exact[v]).abs())
            .sum::<f64>()
            / 2.0;
        assert!(
            tv < 0.09,
            "mock (acceptance {acc:?}): committed-stream TV {tv:.4} from the verifier marginal"
        );
    }
}

#[test]
fn mock_server_streaming_round_trip() {
    let tok = mock_tokenizer();
    let mut engine = EchoEngine::new(2, 64, 0);
    let (addr, rx, lh) = start_frontend(1, 16, 64);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        c.stream_generate(r#"{"op":"generate","prompt":"hi","max_tokens":8,"stream":true}"#)
    });
    server::engine_loop(&rx, &tok, &mut engine).expect("engine_loop");
    lh.join().unwrap();
    let (text, ntok, done) = client.join().unwrap();
    // deltas sum to the terminal frame's authoritative text
    assert_eq!(done.get("text").unwrap().as_str(), Some(text.as_str()));
    assert_eq!(done.get("tokens").unwrap().as_i64(), Some(ntok));
    assert_eq!(done.get("finish_reason").unwrap().as_str(), Some("length"));
    assert!(done.get("latency_ms").unwrap().as_f64().unwrap() >= 0.0);
    // echo decode from token 10: 8 tokens -> "hijklmno"
    assert_eq!(text, "hijklmno");
    assert!(!engine.has_work());
    assert_eq!(engine.metrics().requests_done, 1);
}

#[test]
fn mock_server_cancel_frees_slot_and_stats_report() {
    let tok = mock_tokenizer();
    // batch 1: the cancelled request must actually free its slot for
    // the follow-up request to complete
    let mut engine = EchoEngine::new(1, 512, 3);
    let (addr, rx, lh) = start_frontend(1, 16, 512);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        c.send(r#"{"op":"generate","prompt":"hi","max_tokens":400,"stream":true}"#);
        let first = c.recv();
        let id = first.get("id").expect("delta carries id").as_i64().unwrap();
        c.send(&format!(r#"{{"op":"cancel","id":{id}}}"#));
        // in-flight deltas may precede the terminal frame; the ack
        // follows it on the same channel
        let term = loop {
            let j = c.recv();
            if j.get("done").is_some() {
                break j;
            }
            assert!(j.get("delta").is_some(), "unexpected frame: {j:?}");
        };
        let ack = c.recv();
        // the freed slot admits a fresh request immediately
        c.send(r#"{"prompt":"yo","max_tokens":4}"#);
        let second = c.recv();
        c.send(r#"{"op":"stats"}"#);
        let stats = c.recv();
        (term, ack, second, stats)
    });
    server::engine_loop(&rx, &tok, &mut engine).expect("engine_loop");
    lh.join().unwrap();
    let (term, ack, second, stats) = client.join().unwrap();
    assert_eq!(term.get("finish_reason").unwrap().as_str(), Some("cancelled"));
    assert!(ack.get("cancelled").is_some(), "cancel ack: {ack:?}");
    assert_eq!(second.get("finish_reason").unwrap().as_str(), Some("length"));
    assert_eq!(second.get("tokens").unwrap().as_i64(), Some(4));
    // the /stats surface reports the cancel and the drained queue
    assert_eq!(stats.get("engine").unwrap().as_str(), Some("mock"));
    assert_eq!(stats.get("queue_depth").unwrap().as_i64(), Some(0));
    assert_eq!(stats.get("active").unwrap().as_i64(), Some(0));
    assert_eq!(stats.get("cancelled").unwrap().as_i64(), Some(1));
    assert_eq!(stats.get("requests_done").unwrap().as_i64(), Some(1));
    for key in ["queue_p50_ms", "queue_p99_ms", "acceptance_rate", "wall_tok_s", "virt_tok_s"] {
        assert!(stats.get(key).is_some(), "stats missing {key}");
    }
    assert_eq!(engine.metrics().cancelled, 1);
    assert!(!engine.has_work(), "cancelled request still occupies the engine");
}

#[test]
fn mock_server_disconnect_cancels_in_flight_request() {
    let tok = mock_tokenizer();
    let mut engine = EchoEngine::new(1, 512, 3);
    let (addr, rx, lh) = start_frontend(2, 16, 512);
    let client = thread::spawn(move || {
        {
            let mut c1 = Client::connect(&addr);
            c1.send(r#"{"op":"generate","prompt":"hi","max_tokens":400,"stream":true}"#);
            let _ = c1.recv(); // generation under way
        } // c1 dropped: client hangs up mid-stream
        // the disconnect must free the (only) slot for this request
        let mut c2 = Client::connect(&addr);
        c2.send(r#"{"prompt":"yo","max_tokens":4}"#);
        c2.recv()
    });
    server::engine_loop(&rx, &tok, &mut engine).expect("engine_loop");
    lh.join().unwrap();
    let second = client.join().unwrap();
    assert_eq!(second.get("finish_reason").unwrap().as_str(), Some("length"));
    assert_eq!(engine.metrics().cancelled, 1, "disconnect did not cancel");
    assert_eq!(engine.metrics().requests_done, 1);
    assert!(!engine.has_work());
}

#[test]
fn mock_server_stop_sequence_legacy_form_and_errors() {
    let tok = mock_tokenizer();
    let mut engine = EchoEngine::new(2, 64, 0);
    let (addr, rx, lh) = start_frontend(1, 16, 64);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // mock emits "hijk..." -> stop "jk" trims the output to "hi"
        c.send(r#"{"op":"generate","prompt":"x","max_tokens":20,"stop":["jk"]}"#);
        let stopped = c.recv();
        // the legacy bare-prompt line is still answered correctly
        c.send(r#"{"prompt":"x","max_tokens":3}"#);
        let legacy = c.recv();
        c.send(r#"{"prompt":5}"#);
        let bad_prompt = c.recv();
        c.send(r#"{"op":"zap"}"#);
        let bad_op = c.recv();
        c.send(r#"{"op":"cancel","id":999}"#);
        let not_found = c.recv();
        // stop entries are re-validated after tokenization: 40 chars
        // pass the parse layer but encode to 40 tokens > the ceiling
        c.send(&format!(
            r#"{{"op":"generate","prompt":"x","stop":["{}"]}}"#,
            "a".repeat(40)
        ));
        let bad_stop = c.recv();
        // temperature parses (within [0,2]) and the mock serves it
        // through the stochastic sampler (v1.6): a normal completion,
        // not a bad_request
        c.send(r#"{"op":"generate","prompt":"x","max_tokens":4,"temperature":0.7,"seed":9}"#);
        let sampled = c.recv();
        // temperature 0 on the same engine stays greedy
        c.send(r#"{"op":"generate","prompt":"x","max_tokens":3,"temperature":0}"#);
        let temp_zero = c.recv();
        (stopped, legacy, bad_prompt, bad_op, not_found, bad_stop, sampled, temp_zero)
    });
    server::engine_loop(&rx, &tok, &mut engine).expect("engine_loop");
    lh.join().unwrap();
    let (stopped, legacy, bad_prompt, bad_op, not_found, bad_stop, sampled, temp_zero) =
        client.join().unwrap();
    assert_eq!(stopped.get("finish_reason").unwrap().as_str(), Some("stop"));
    assert_eq!(stopped.get("text").unwrap().as_str(), Some("hi"));
    // the [j, k] match spans two single-token commits; the counters are
    // reconciled to the delivered outputs ("hi" + "hij" + "hij")
    assert_eq!(legacy.get("finish_reason").unwrap().as_str(), Some("length"));
    assert_eq!(legacy.get("text").unwrap().as_str(), Some("hij"));
    let err = bad_prompt.get("error").expect("error frame");
    assert_eq!(err.get("code").unwrap().as_str(), Some("bad_request"));
    assert!(err.get("message").unwrap().as_str().unwrap().contains("prompt"));
    let err = bad_op.get("error").expect("error frame");
    assert!(err.get("message").unwrap().as_str().unwrap().contains("zap"));
    let err = not_found.get("error").expect("error frame");
    assert_eq!(err.get("code").unwrap().as_str(), Some("not_found"));
    let err = bad_stop.get("error").expect("error frame");
    assert_eq!(err.get("code").unwrap().as_str(), Some("bad_request"));
    assert!(err.get("message").unwrap().as_str().unwrap().contains("stop"));
    assert!(
        sampled.get("error").is_none(),
        "v1.6 engines with logits support serve temperature > 0: {sampled:?}"
    );
    let fr = sampled.get("finish_reason").unwrap().as_str().unwrap();
    assert!(fr == "length" || fr == "stop", "sampled request completes, got {fr}");
    assert_eq!(temp_zero.get("finish_reason").unwrap().as_str(), Some("length"));
    assert_eq!(engine.metrics().requests_done, 4);
}

#[test]
fn mock_server_cancel_is_connection_scoped() {
    let tok = mock_tokenizer();
    let mut engine = EchoEngine::new(1, 512, 3);
    let (addr, rx, lh) = start_frontend(2, 16, 512);
    let client = thread::spawn(move || {
        let mut c1 = Client::connect(&addr);
        c1.send(r#"{"op":"generate","prompt":"hi","max_tokens":400,"stream":true}"#);
        let first = c1.recv();
        let id = first.get("id").expect("delta id").as_i64().unwrap();
        // ids are guessable (sequential); a different connection must
        // not be able to cancel someone else's request
        let mut c2 = Client::connect(&addr);
        c2.send(&format!(r#"{{"op":"cancel","id":{id}}}"#));
        let foreign = c2.recv();
        drop(c2);
        // the owning connection still can
        c1.send(&format!(r#"{{"op":"cancel","id":{id}}}"#));
        let term = loop {
            let j = c1.recv();
            if j.get("done").is_some() {
                break j;
            }
        };
        let ack = c1.recv();
        (foreign, term, ack)
    });
    server::engine_loop(&rx, &tok, &mut engine).expect("engine_loop");
    lh.join().unwrap();
    let (foreign, term, ack) = client.join().unwrap();
    let err = foreign.get("error").expect("foreign cancel must fail");
    assert_eq!(err.get("code").unwrap().as_str(), Some("not_found"));
    assert_eq!(term.get("finish_reason").unwrap().as_str(), Some("cancelled"));
    assert!(ack.get("cancelled").is_some(), "owner cancel acked: {ack:?}");
    assert_eq!(engine.metrics().cancelled, 1);
    assert!(!engine.has_work());
}

/// Protocol v1.1 QoS end-to-end over real TCP against the mock engine:
/// priority scheduling, SLO-based shedding (`overloaded` frame with
/// `retry_after_ms`), deadline expiry (`deadline_exceeded` terminal),
/// and the extended stats snapshot.
#[test]
fn mock_server_qos_priority_shedding_and_deadlines() {
    let tok = mock_tokenizer();
    // batch 1 + priority policy + a depth-1 SLO: one long request pins
    // the slot, everything else exercises the queue
    let mut engine = EchoEngine::new(1, 512, 3);
    engine.core_mut().set_policy(build_policy(SchedKind::Priority));
    engine.core_mut().set_slo(SloConfig {
        max_queue_depth: Some(1),
        retry_after_ms: 250,
        ..SloConfig::default()
    });
    let (addr, rx, lh) = start_frontend(1, 16, 512);
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // A: long streamed generation — pins the single slot
        c.send(r#"{"op":"generate","prompt":"hi","max_tokens":400,"stream":true}"#);
        let first = c.recv();
        let id_a = first.get("id").expect("delta id").as_i64().unwrap();
        // B: legacy frame -> queued behind A (depth 1, default class)
        c.send(r#"{"prompt":"yo","max_tokens":4}"#);
        // C: background class while depth >= 1 -> shed with retry hint
        c.send(r#"{"op":"generate","prompt":"no","max_tokens":4,"priority":0}"#);
        // D: critical class -> exempt from shedding, jumps the queue
        c.send(r#"{"op":"generate","prompt":"go","max_tokens":4,"priority":3}"#);
        // E: high class with a 1ms budget -> admitted, but its deadline
        // lapses while A still holds the slot
        c.send(r#"{"op":"generate","prompt":"dl","max_tokens":4,"priority":2,"deadline_ms":1}"#);
        c.send(&format!(r#"{{"op":"cancel","id":{id_a}}}"#));
        // collect frames until A's terminal + ack + C's error + the
        // three queued terminals have all arrived
        let mut overload = None;
        let mut ack = None;
        let mut terminals: Vec<Json> = Vec::new();
        while overload.is_none() || ack.is_none() || terminals.len() < 4 {
            let j = c.recv();
            if j.get("error").is_some() {
                overload = Some(j);
            } else if j.get("cancelled").is_some() {
                ack = Some(j);
            } else if j.get("finish_reason").is_some() {
                terminals.push(j);
            } else {
                assert!(j.get("delta").is_some(), "unexpected frame: {j:?}");
            }
        }
        c.send(r#"{"op":"stats"}"#);
        let stats = c.recv();
        (id_a, overload.unwrap(), ack.unwrap(), terminals, stats)
    });
    server::engine_loop(&rx, &tok, &mut engine).expect("engine_loop");
    lh.join().unwrap();
    let (id_a, overload, ack, terminals, stats) = client.join().unwrap();

    // C was shed with the structured overloaded frame
    let err = overload.get("error").unwrap();
    assert_eq!(err.get("code").unwrap().as_str(), Some("overloaded"));
    assert_eq!(err.get("retry_after_ms").unwrap().as_i64(), Some(250));
    assert!(ack.get("cancelled").is_some());

    // terminal frames: A cancelled, D + B finished, E expired. Ids are
    // engine-assigned in submission order and the shed C never got
    // one, so B/D/E are id_a+1/+2/+3.
    let (id_b, id_d, id_e) = (id_a + 1, id_a + 2, id_a + 3);
    let reason = |j: &Json| j.get("finish_reason").unwrap().as_str().unwrap().to_string();
    let terminal = |id: i64| {
        terminals
            .iter()
            .position(|j| j.get("id").unwrap().as_i64() == Some(id))
            .unwrap_or_else(|| panic!("no terminal frame for id {id}"))
    };
    assert_eq!(terminals.len(), 4);
    assert_eq!(reason(&terminals[terminal(id_a)]), "cancelled");
    let d = terminal(id_d);
    assert_eq!(reason(&terminals[d]), "length");
    assert_eq!(terminals[d].get("tokens").unwrap().as_i64(), Some(4));
    let e = terminal(id_e);
    assert_eq!(reason(&terminals[e]), "deadline_exceeded");
    assert_eq!(terminals[e].get("tokens").unwrap().as_i64(), Some(0), "E never ran");
    let b = terminal(id_b);
    assert_eq!(reason(&terminals[b]), "length");
    // the priority scheduler visibly at work: D (critical, submitted
    // last) completes before B (normal, submitted first)
    assert!(d < b, "critical request must finish before the earlier normal one");

    // the v1.1 stats surface
    assert_eq!(stats.get("engine").unwrap().as_str(), Some("mock"));
    assert_eq!(stats.get("sched").unwrap().as_str(), Some("priority"));
    assert_eq!(stats.get("slots").unwrap().as_i64(), Some(1));
    assert_eq!(stats.get("active").unwrap().as_i64(), Some(0));
    assert_eq!(stats.get("queue_depth").unwrap().as_i64(), Some(0));
    let depths = stats.get("queue_depth_by_priority").unwrap().as_arr().unwrap();
    assert_eq!(depths.len(), 4);
    assert!(depths.iter().all(|d| d.as_i64() == Some(0)));
    assert_eq!(stats.get("shed").unwrap().as_i64(), Some(1));
    assert_eq!(stats.get("deadline_expired").unwrap().as_i64(), Some(1));
    assert_eq!(stats.get("requests_done").unwrap().as_i64(), Some(2));
    assert_eq!(stats.get("cancelled").unwrap().as_i64(), Some(1));
    // the mock never drafts: acceptance is null, not a misleading 0.0
    assert_eq!(stats.get("acceptance_rate"), Some(&Json::Null));

    assert_eq!(engine.metrics().shed, 1);
    assert_eq!(engine.metrics().deadline_expired, 1);
    assert!(!engine.has_work());
}

// ---------------------------------------------------------------------------
// artifact-gated layer: real engines through the same battery + TCP
// ---------------------------------------------------------------------------

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// The sweep every conformance run covers. The inner match is
/// exhaustive over `EngineKind` on purpose: adding a variant fails to
/// compile here until the new engine kind is added to the sweep (and
/// therefore to the battery).
fn conformance_kinds() -> Vec<(EngineKind, &'static str)> {
    fn covered(k: &EngineKind) {
        match k {
            EngineKind::QSpec
            | EngineKind::Ar(_)
            | EngineKind::Eagle { .. }
            | EngineKind::HierSpec { .. }
            | EngineKind::TreeSpec { .. } => {}
        }
    }
    let kinds = vec![
        (EngineKind::QSpec, "s"),
        (EngineKind::Ar(Mode::W4A16), "s"),
        (EngineKind::Eagle { tree_k: 1 }, "m"),
        (EngineKind::HierSpec { gamma: 3, kv_bits: 4 }, "s"),
        (EngineKind::TreeSpec { width: 2, depth: 4 }, "s"),
    ];
    for (k, _) in &kinds {
        covered(k);
    }
    kinds
}

#[test]
fn engine_trait_suite() {
    if !artifacts_root().join("manifest.json").exists() {
        eprintln!("skipping engine_trait: run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::open(&artifacts_root()).expect("manifest");
    let sess = Session::new(store).expect("session");
    let tok = Tokenizer::load(&sess.store.tokenizer_path()).expect("tokenizer");
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval set");
    let prompts: Vec<String> = items.iter().take(12).map(|i| i.prompt.clone()).collect();

    // the identical battery drives every engine kind
    for (kind, size) in conformance_kinds() {
        let cfg = ServeConfig {
            size: size.to_string(),
            batch: 8,
            engine: kind.clone(),
            ..ServeConfig::default()
        };
        let mut engine = build_engine(&sess, &cfg).expect("build_engine");
        eprintln!("conformance: engine={} size={size}", engine.name());
        conformance(engine.as_mut(), &tok, &prompts);
    }
    for (kind, size) in conformance_kinds() {
        server_scenarios(&sess, &tok, kind, size, &prompts);
    }
    hierspec_losslessness(&sess, &tok, &prompts);
    treespec_losslessness(&sess, &tok, &prompts);
    stochastic_losslessness_sweep(&sess, &tok, &prompts[0]);
}

/// The HierSpec losslessness invariant, end-to-end: its draft phase is
/// lossy (acceptance < 1.0 through the quantized shadow) but the
/// committed output must equal the verifier's — and the verifier IS
/// the W4A16 model, so HierSpec output must match the W4A16 AR
/// baseline token-for-token on the same prompts.
fn hierspec_losslessness(sess: &Session, tok: &Tokenizer, prompts: &[String]) {
    let run = |kind: EngineKind| {
        let cfg = ServeConfig {
            size: "s".to_string(),
            batch: 8,
            engine: kind,
            ..ServeConfig::default()
        };
        let mut engine = build_engine(sess, &cfg).expect("engine");
        for p in prompts {
            engine.submit_request(greedy(tok, p, 24));
        }
        let mut fins = engine.run_to_completion().expect("run");
        fins.sort_by_key(|f| f.id);
        let outs: Vec<Vec<i32>> = fins.into_iter().map(|f| f.tokens).collect();
        let acc = engine.metrics().acceptance_rate_opt();
        (outs, acc)
    };
    let (baseline, _) = run(EngineKind::Ar(Mode::W4A16));
    let (hier, acc) = run(EngineKind::HierSpec { gamma: 3, kv_bits: 4 });
    assert_eq!(
        hier, baseline,
        "hierspec committed output must equal the W4A16 verifier exactly"
    );
    let acc = acc.expect("hierspec drafts");
    assert!(acc > 0.0, "a 4-bit shadow must still accept some drafts ({acc})");
    assert!(acc < 1.0, "a 4-bit shadow must be measurably lossy ({acc})");
    eprintln!("hierspec losslessness: outputs match w4a16, acceptance {:.1}%", 100.0 * acc);
}

/// The v1.7 TreeSpec losslessness invariant, end-to-end: whatever
/// branches the W4A4 tree draft offers and whichever root path the
/// tree acceptance commits, the greedy committed stream must equal the
/// W4A16 AR baseline token-for-token — the verifier chain is the sole
/// author of the output. Also pins the tree counters: a tree engine
/// that never drafted a sibling or never recorded an accepted depth is
/// silently running linear.
fn treespec_losslessness(sess: &Session, tok: &Tokenizer, prompts: &[String]) {
    let run = |kind: EngineKind| {
        let cfg = ServeConfig {
            size: "s".to_string(),
            batch: 8,
            engine: kind,
            ..ServeConfig::default()
        };
        let mut engine = build_engine(sess, &cfg).expect("engine");
        for p in prompts {
            engine.submit_request(greedy(tok, p, 24));
        }
        let mut fins = engine.run_to_completion().expect("run");
        fins.sort_by_key(|f| f.id);
        let outs: Vec<Vec<i32>> = fins.into_iter().map(|f| f.tokens).collect();
        let m = engine.metrics().clone();
        (outs, m)
    };
    let (baseline, _) = run(EngineKind::Ar(Mode::W4A16));
    let (spec, m) = run(EngineKind::TreeSpec { width: 2, depth: 4 });
    assert_eq!(
        spec, baseline,
        "treespec committed output must equal the W4A16 verifier exactly"
    );
    assert!(m.tree_nodes_drafted > 0, "treespec never drafted a tree node");
    assert!(m.tree_paths > 0, "treespec never offered a root path");
    assert!(m.accepted_depth.count() > 0, "treespec never recorded an accepted depth");
    eprintln!(
        "treespec losslessness: outputs match w4a16, {} nodes over {} paths, accepted depth p50 {}",
        m.tree_nodes_drafted,
        m.tree_paths,
        m.accepted_depth.percentile(50.0)
    );
}

/// The v1.7 stochastic-losslessness sweep: satellite of the tree PR —
/// the empirical TV property graduates from the toy mock
/// (`mock_stochastic_stream_is_distributed_as_the_verifier_chain`) to
/// the real engines. Every drafting engine serving `temperature > 0`
/// must commit a stream distributed as its *verifier* chain, so the
/// second committed token's empirical marginal must match the W4A16 AR
/// baseline's up to sampling noise. The noise floor is self-calibrated
/// — two independent AR baselines of the same trial count measure it —
/// so the bound holds for any tokenizer vocabulary. A broken accept
/// rule (committing draft samples directly) sits an order of magnitude
/// above it.
fn stochastic_losslessness_sweep(sess: &Session, tok: &Tokenizer, prompt: &str) {
    use std::collections::HashMap;

    const TEMP: f32 = 0.7;
    const N: usize = 800;

    // empirical marginal of the second committed token over N seeded
    // single-prompt runs, submitted in batch-size waves to amortize
    // scheduling cycles. Returns None when the artifact set is
    // argmax-only (pre-logits sets cannot serve temperature > 0).
    let hist = |kind: EngineKind, size: &str, seed_base: u64| -> Option<HashMap<i32, f64>> {
        let cfg = ServeConfig {
            size: size.to_string(),
            batch: 8,
            engine: kind,
            ..ServeConfig::default()
        };
        let mut engine = build_engine(sess, &cfg).expect("engine");
        if engine.argmax_only() {
            return None;
        }
        let toks = tok.encode_prompt(prompt);
        let mut counts: HashMap<i32, u64> = HashMap::new();
        let mut n = 0u64;
        let mut submitted = 0usize;
        while submitted < N {
            let wave = 8.min(N - submitted);
            for w in 0..wave {
                let params = SamplingParams {
                    max_tokens: 2,
                    temperature: TEMP,
                    seed: seed_base + (submitted + w) as u64,
                    ..SamplingParams::default()
                };
                engine.submit_request(GenerationRequest::new(toks.clone(), params));
            }
            submitted += wave;
            for f in engine.run_to_completion().expect("sampled run") {
                // EOS-at-one runs carry no second token; skip them the
                // same way for every engine so the marginals compare
                if let Some(&t) = f.tokens.get(1) {
                    *counts.entry(t).or_insert(0) += 1;
                    n += 1;
                }
            }
        }
        assert!(n as usize > N / 2, "{kind:?}: too many EOS-terminated runs ({n}/{N})");
        Some(counts.into_iter().map(|(t, c)| (t, c as f64 / n as f64)).collect())
    };
    let tv = |a: &HashMap<i32, f64>, b: &HashMap<i32, f64>| -> f64 {
        let mut support: Vec<i32> = a.keys().chain(b.keys()).copied().collect();
        support.sort_unstable();
        support.dedup();
        support
            .iter()
            .map(|t| (a.get(t).unwrap_or(&0.0) - b.get(t).unwrap_or(&0.0)).abs())
            .sum::<f64>()
            / 2.0
    };

    // per-size AR(W4A16) baselines: Eagle artifacts live at "m", the
    // rest at "s"; each drafting engine compares against the baseline
    // of its own model size
    for (size, engines) in [
        (
            "s",
            vec![
                ("qspec", EngineKind::QSpec),
                ("hierspec", EngineKind::HierSpec { gamma: 3, kv_bits: 4 }),
                ("treespec", EngineKind::TreeSpec { width: 2, depth: 4 }),
            ],
        ),
        ("m", vec![("eagle", EngineKind::Eagle { tree_k: 1 })]),
    ] {
        let Some(base_a) = hist(EngineKind::Ar(Mode::W4A16), size, 900_000) else {
            eprintln!("stochastic sweep: size {size} is argmax-only, skipping");
            continue;
        };
        let base_b = hist(EngineKind::Ar(Mode::W4A16), size, 910_000).expect("second baseline");
        // the measured AR-vs-AR sampling noise at this N and vocab,
        // with an absolute floor against a lucky near-zero draw
        let noise = tv(&base_a, &base_b).max(0.02);
        for (name, kind) in engines {
            let Some(h) = hist(kind, size, 920_000) else {
                eprintln!("stochastic sweep: {name} is argmax-only, skipping");
                continue;
            };
            let d = tv(&h, &base_a);
            eprintln!("stochastic sweep: {name}@{size} TV {d:.4} (noise floor {noise:.4})");
            assert!(
                d < noise * 3.0,
                "{name}: committed-stream TV {d:.4} vs AR baseline exceeds 3x the \
                 measured sampling noise {noise:.4} — sampled serving is not lossless"
            );
        }
    }
}

/// The protocol-v1 acceptance scenario, against a real engine over real
/// TCP: streaming round trip, stop-sequence termination, explicit
/// cancellation (slot verifiably freed), a stats snapshot, and a
/// disconnect-driven cancellation.
fn server_scenarios(
    sess: &Session,
    tok: &Tokenizer,
    kind: EngineKind,
    size: &str,
    prompts: &[String],
) {
    let cfg = ServeConfig {
        size: size.to_string(),
        batch: 8,
        engine: kind,
        ..ServeConfig::default()
    };
    let mut engine = build_engine(sess, &cfg).expect("engine");
    let name = engine.name();
    let cap = engine.max_seq();
    let (addr, rx, lh) = start_frontend(2, cfg.max_tokens_default, cap);
    let p0 = prompts[0].replace('\n', "\\n");
    let p1 = prompts[1].replace('\n', "\\n");
    let client = thread::spawn(move || {
        let mut c = Client::connect(&addr);
        // 1. streaming round trip
        let (text, ntok, done) = c.stream_generate(&format!(
            r#"{{"op":"generate","prompt":"{p0}","max_tokens":24,"stream":true}}"#
        ));
        assert_eq!(done.get("text").unwrap().as_str(), Some(text.as_str()), "delta sum != final");
        assert_eq!(done.get("tokens").unwrap().as_i64(), Some(ntok));
        assert!(ntok > 0);
        // 2. stop sequence derived from the (deterministic greedy) text
        let stop: String = text.chars().skip(1).take(2).collect();
        if stop.chars().count() == 2 {
            c.send(&format!(
                r#"{{"op":"generate","prompt":"{p0}","max_tokens":24,"stop":["{}"]}}"#,
                stop.replace('\n', "\\n")
            ));
            let stopped = c.recv();
            assert_eq!(
                stopped.get("finish_reason").unwrap().as_str(),
                Some("stop"),
                "stop sequence ignored"
            );
            let t2 = stopped.get("text").unwrap().as_str().unwrap().to_string();
            assert!(!t2.contains(&stop), "matched stop not trimmed: {t2:?}");
            assert!(text.starts_with(&t2), "stop run diverged: {t2:?} vs {text:?}");
        }
        // 3. explicit cancel mid-flight
        c.send(&format!(
            r#"{{"op":"generate","prompt":"{p1}","max_tokens":{cap},"stream":true}}"#
        ));
        let first = c.recv();
        let id = first.get("id").expect("delta id").as_i64().unwrap();
        c.send(&format!(r#"{{"op":"cancel","id":{id}}}"#));
        let term = loop {
            let j = c.recv();
            if j.get("done").is_some() {
                break j;
            }
        };
        assert_eq!(term.get("finish_reason").unwrap().as_str(), Some("cancelled"));
        let ack = c.recv();
        assert!(ack.get("cancelled").is_some(), "no cancel ack: {ack:?}");
        // 4. stats snapshot (slot freed by the cancel)
        c.send(r#"{"op":"stats"}"#);
        let stats = c.recv();
        assert_eq!(stats.get("active").unwrap().as_i64(), Some(0), "slot not freed");
        assert_eq!(stats.get("queue_depth").unwrap().as_i64(), Some(0));
        assert_eq!(stats.get("cancelled").unwrap().as_i64(), Some(1));
        for key in [
            "queue_p50_ms", "queue_p99_ms", "acceptance_rate", "wall_tok_s", "virt_tok_s",
        ] {
            assert!(stats.get(key).is_some(), "stats missing {key}");
        }
        drop(c);
        // 5. disconnect-driven cancellation on a fresh connection
        let mut c2 = Client::connect(&addr);
        c2.send(&format!(
            r#"{{"op":"generate","prompt":"{p1}","max_tokens":{cap},"stream":true}}"#
        ));
        let _ = c2.recv(); // at least one delta: the request is running
    });
    server::engine_loop(&rx, &tok, engine.as_mut()).expect("engine_loop");
    lh.join().unwrap();
    client.join().unwrap();
    assert_eq!(engine.metrics().cancelled, 2, "{name}: expected 2 cancellations");
    assert!(!engine.has_work(), "{name}: work left after disconnect");
}
