//! Trait-level engine tests: every engine kind is driven through the
//! same generic harness (`&mut dyn Engine`), and the server loop is
//! round-tripped with the EAGLE baseline — servable since the engine
//! abstraction landed.
//!
//! Requires `make artifacts` (skips silently otherwise). One #[test]
//! drives everything: PJRT client creation is expensive and the handles
//! are not Send, so a single test owns the session.

use std::path::PathBuf;
use std::sync::mpsc;

use qspec::config::{EngineKind, ServeConfig};
use qspec::coordinator::{build_engine, Engine};
use qspec::evalsuite;
use qspec::model::{Mode, Tokenizer};
use qspec::runtime::{ArtifactStore, Session};
use qspec::server::{self, InboundRequest};
use qspec::util::json::{num, obj, s, Json};

fn artifacts_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[test]
fn engine_trait_suite() {
    if !artifacts_root().join("manifest.json").exists() {
        eprintln!("skipping engine_trait: run `make artifacts` first");
        return;
    }
    let store = ArtifactStore::open(&artifacts_root()).expect("manifest");
    let sess = Session::new(store).expect("session");
    let tok = Tokenizer::load(&sess.store.tokenizer_path()).expect("tokenizer");
    let items = evalsuite::load_eval(&sess.store.eval_path("chain")).expect("eval set");
    let prompts: Vec<String> = items.iter().take(12).map(|i| i.prompt.clone()).collect();

    // the same harness drives every engine kind
    let kinds: Vec<(EngineKind, &str)> = vec![
        (EngineKind::QSpec, "s"),
        (EngineKind::Ar(Mode::W4A16), "s"),
        (EngineKind::Eagle { tree_k: 1 }, "m"),
    ];
    for (kind, size) in &kinds {
        let cfg = ServeConfig {
            size: size.to_string(),
            batch: 8,
            engine: kind.clone(),
            ..ServeConfig::default()
        };
        let mut engine = build_engine(&sess, &cfg).expect("build_engine");
        drive_generic(engine.as_mut(), &tok, &prompts);
    }

    eagle_server_round_trip(&sess, &tok, &prompts);
}

/// Submit N requests -> run_to_completion -> assert every request
/// finishes, completion covers exactly the FCFS-assigned ids, and the
/// metrics invariants hold for ANY engine.
fn drive_generic(engine: &mut dyn Engine, tok: &Tokenizer, prompts: &[String]) {
    let n = prompts.len();
    let mut submitted = Vec::new();
    for p in prompts {
        submitted.push(engine.submit(tok.encode_prompt(p), 24));
    }
    // ids are engine-assigned, dense and in submission order
    assert_eq!(submitted, (0..n as u64).collect::<Vec<_>>(), "{}", engine.name());
    assert!(engine.has_work());

    let mut fins = engine.run_to_completion().expect("run_to_completion");
    assert!(!engine.has_work(), "{}: work left after completion", engine.name());
    assert_eq!(fins.len(), n, "{}: all requests must finish", engine.name());
    fins.sort_by_key(|f| f.id);
    let ids: Vec<u64> = fins.iter().map(|f| f.id).collect();
    assert_eq!(ids, submitted, "{}: finished ids != submitted ids", engine.name());

    let m = engine.metrics();
    assert_eq!(m.requests_done, n as u64, "{}", engine.name());
    // every engine counts exactly the emitted tokens as committed
    assert_eq!(m.committed, m.tokens_out, "{}", engine.name());
    let toks: usize = fins.iter().map(|f| f.tokens.len()).sum();
    assert_eq!(toks as u64, m.tokens_out, "{}", engine.name());
    // the new queue-wait histogram sees one admission per request
    assert_eq!(m.queue_wait.count(), n as u64, "{}", engine.name());
    assert_eq!(m.req_latency.count(), n as u64, "{}", engine.name());
    for f in &fins {
        assert!(f.latency_ns >= f.queue_ns, "{}: wait > latency", engine.name());
    }
    // the virtual clock advanced (every phase charges it)
    assert!(engine.cost().virtual_ns > 0, "{}", engine.name());
}

/// Server-layer round trip for the newly servable EAGLE engine: the
/// engine loop is driven through the same mpsc protocol the TCP
/// connection threads use (requests in, JSON response lines out).
fn eagle_server_round_trip(sess: &Session, tok: &Tokenizer, prompts: &[String]) {
    let cfg = ServeConfig {
        size: "m".to_string(),
        batch: 8,
        engine: EngineKind::Eagle { tree_k: 1 },
        ..ServeConfig::default()
    };
    let mut engine = build_engine(sess, &cfg).expect("eagle engine");
    let cap = engine.max_seq();

    let (tx, rx) = mpsc::channel::<InboundRequest>();
    let mut resp_rx = Vec::new();
    for p in prompts.iter().take(6) {
        // go through the real request parser (clamps max_tokens),
        // serializing with the crate's own JSON writer
        let line = obj(vec![
            ("prompt", s(p)),
            ("max_tokens", num(9_999_999.0)),
        ])
        .to_string();
        let (prompt, max_tokens) =
            server::parse_request_line(&line, cfg.max_tokens_default, cap).expect("parse");
        assert!(max_tokens <= cap, "clamp failed");
        let (rtx, rrx) = mpsc::channel();
        tx.send(InboundRequest { prompt, max_tokens, resp: rtx }).unwrap();
        resp_rx.push(rrx);
    }
    drop(tx); // loop exits once idle and the channel is closed
    server::engine_loop(&rx, tok, engine.as_mut()).expect("engine_loop");

    for rrx in resp_rx {
        let line = rrx.try_recv().expect("response delivered");
        let j = Json::parse(&line).expect("response is JSON");
        assert!(j.get("id").is_some());
        assert!(j.get("latency_ms").is_some());
        assert!(j.get("queue_ms").is_some());
        assert!(j.get("tokens").unwrap().as_i64().unwrap() > 0);
        assert!(j.get("text").unwrap().as_str().is_some());
    }
    assert_eq!(engine.metrics().requests_done, 6);
}
