//! Distributed-transport suite (protocol v1.4): mock workers served
//! over real TCP sockets behind `transport::connect_remote` proxies,
//! driven through the same frontend conn threads + dynamic router as
//! production — so the full cross-host surface (envelope round trip,
//! heartbeat death detection, mid-stream `replica_lost`, queued-work
//! stealing, worker rejoin accounting) runs in CI without artifacts.
//!
//! The last scenario is genuinely two-process: it spawns the real
//! `qspec serve --worker --mock` binary, SIGKILLs it mid-stream, and
//! respawns it on the same address — the closest thing to a cross-host
//! failover a single CI box can stage.

use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use qspec::config::{RouteKind, SloConfig};
use qspec::coordinator::mock::FailureMode;
use qspec::coordinator::EchoEngine;
use qspec::server::transport::{self, RemoteOpts};
use qspec::server::{
    self, Action, AutoscaleConfig, AutoscaleCore, Inbound, PoolLifecycle, ReplicaSample,
    RouterCore,
};
use qspec::util::prng::Pcg32;

mod common;
use common::{mock_tokenizer, Client};

// ---------------------------------------------------------------------------
// harness: in-thread workers + a real router/frontend over TCP proxies
// ---------------------------------------------------------------------------

/// Grab an ephemeral port the worker can (re)bind.
fn free_addr() -> String {
    let l = TcpListener::bind("127.0.0.1:0").expect("probe bind");
    let addr = format!("127.0.0.1:{}", l.local_addr().unwrap().port());
    drop(l);
    addr
}

/// Run `serve_worker` over an `EchoEngine` on a detached thread —
/// process-shaped (own listener, own id space pinned by the adopting
/// router) without the process-spawn cost. The optional fault makes
/// the engine die mid-session exactly like a crashing real worker.
fn spawn_mock_worker(addr: &str, delay_ms: u64, failure: Option<FailureMode>) {
    let addr = addr.to_string();
    thread::spawn(move || {
        let tok = mock_tokenizer();
        let mut engine = EchoEngine::new(8, 512, delay_ms);
        if let Some(mode) = failure {
            engine = engine.with_failure(mode);
        }
        let _ = server::transport::serve_worker(&addr, &tok, &mut engine);
    });
}

/// Poll-connect until the worker's listener is up. The probe itself is
/// harmless: the worker reads EOF where the hello should be and goes
/// back to accepting.
fn wait_listening(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if TcpStream::connect(addr).is_ok() {
            return;
        }
        assert!(Instant::now() < deadline, "worker at {addr} never came up");
        thread::sleep(Duration::from_millis(10));
    }
}

/// Stand up the full remote-pool stack — one proxy per worker address,
/// dynamic router thread, TCP frontend — and return the frontend
/// address. Round-robin routing, default SLO, `retry_after_ms: 250`.
fn start_router(worker_addrs: &[String], steal: bool, n_conns: usize) -> String {
    let n = worker_addrs.len();
    let (rtx, rrx) = mpsc::channel::<Inbound>();
    let mut slots = Vec::new();
    let mut statuses = Vec::new();
    for (k, addr) in worker_addrs.iter().enumerate() {
        wait_listening(addr);
        let remote = transport::connect_remote(
            k,
            n,
            addr,
            rtx.clone(),
            RemoteOpts { steal, retry_after_ms: 250, ..RemoteOpts::default() },
        )
        .expect("worker handshake");
        statuses.push(remote.handle.status.clone());
        slots.push(Some(remote.handle));
    }
    let mut core = RouterCore::new(statuses, RouteKind::RoundRobin, SloConfig::default());
    thread::spawn(move || {
        let mut slots = slots;
        let mut life = PoolLifecycle::new();
        let _ = server::pool::router_loop_dynamic(&rrx, &mut core, &mut slots, &mut life);
    });
    let listener = TcpListener::bind("127.0.0.1:0").expect("frontend bind");
    let addr = format!("127.0.0.1:{}", listener.local_addr().unwrap().port());
    thread::spawn(move || {
        for conn in 0..n_conns as u64 {
            let Ok((stream, _)) = listener.accept() else { return };
            let rtx = rtx.clone();
            thread::spawn(move || server::conn_thread(stream, conn + 1, rtx, 16, 512));
        }
    });
    addr
}

/// Poll the router's pooled stats until the cumulative `restarts`
/// counter reaches `want` (a worker rejoined) or the deadline passes.
fn wait_for_restarts(c: &mut Client, want: i64, secs: u64) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    loop {
        c.send(r#"{"op":"stats"}"#);
        let (stats, _) = c.recv_until(|j| j.get("restarts").is_some());
        if stats.get("restarts").unwrap().as_i64().unwrap() >= want {
            return;
        }
        assert!(Instant::now() < deadline, "no rejoin: restarts never reached {want}");
        thread::sleep(Duration::from_millis(50));
    }
}

// ---------------------------------------------------------------------------
// scenarios
// ---------------------------------------------------------------------------

/// A healthy remote worker is indistinguishable from a local replica:
/// streaming and non-streaming generates round-trip through the proxy,
/// and the pooled stats carry the replica table + v1.4 lifecycle
/// counters (all zero while nothing has died).
#[test]
fn remote_round_trip_streams_and_stats() {
    let waddr = free_addr();
    spawn_mock_worker(&waddr, 0, None);
    let front = start_router(&[waddr], true, 2);
    let mut c = Client::connect(&front);

    let (text, ntok, done) = c.stream_generate(
        r#"{"op":"generate","prompt":"q: remote hello ?\n","max_tokens":12,"stream":true}"#,
    );
    assert!(!text.is_empty() && ntok > 0);
    assert_eq!(done.get("finish_reason").unwrap().as_str(), Some("length"));

    c.send(r#"{"op":"generate","prompt":"q: once more ?\n","max_tokens":8,"stream":false}"#);
    let (j, _) = c.recv_until(|j| j.get("done").is_some() || j.get("error").is_some());
    assert!(j.get("error").is_none(), "healthy remote must answer: {j:?}");
    assert_eq!(j.get("tokens").unwrap().as_i64(), Some(8));

    c.send(r#"{"op":"stats"}"#);
    let (stats, _) = c.recv_until(|j| j.get("restarts").is_some());
    assert_eq!(stats.get("restarts").unwrap().as_i64(), Some(0));
    assert_eq!(stats.get("stolen").unwrap().as_i64(), Some(0));
    assert_eq!(stats.get("lost_streams").unwrap().as_i64(), Some(0));
    let reps = stats.get("replicas").unwrap().as_arr().unwrap();
    assert_eq!(reps.len(), 1);
}

/// A worker that dies mid-stream turns into a structured, retryable
/// error on the client: `replica_lost` carrying the pool's
/// `retry_after_ms` hint — never a silent hang or a dropped socket.
#[test]
fn dead_worker_mid_stream_answers_replica_lost() {
    let waddr = free_addr();
    spawn_mock_worker(&waddr, 10, Some(FailureMode::DropConn(5)));
    // steal off: even a not-yet-streamed generate answers replica_lost,
    // so the assertion cannot race the first delta
    let front = start_router(&[waddr], false, 2);
    let mut c = Client::connect(&front);

    c.send(r#"{"op":"generate","prompt":"q: doomed ?\n","max_tokens":400,"stream":true}"#);
    let (j, _) = c.recv_until(|j| j.get("error").is_some());
    let err = j.get("error").unwrap();
    assert_eq!(err.get("code").unwrap().as_str(), Some("replica_lost"));
    assert_eq!(err.get("retry_after_ms").unwrap().as_i64(), Some(250));
}

/// Work queued on a dying replica is not lost: the proxy re-admits its
/// un-streamed generates to the router, which places them on the
/// survivor — every request completes and the pooled `stolen` counter
/// records the transfer.
#[test]
fn queued_work_is_stolen_to_a_survivor() {
    let w0 = free_addr();
    let w1 = free_addr();
    // w0 is slow and dies after a couple of cycles; w1 is healthy
    spawn_mock_worker(&w0, 30, Some(FailureMode::DropConn(2)));
    spawn_mock_worker(&w1, 0, None);
    let front = start_router(&[w0, w1], true, 2);
    let mut c = Client::connect(&front);

    for i in 0..6 {
        c.send(&format!(
            r#"{{"op":"generate","prompt":"q: job{i} ?\n","max_tokens":24,"stream":false}}"#
        ));
    }
    // non-streamed generates are always steal-eligible, so all six
    // must finish even though half were placed on the doomed replica
    for _ in 0..6 {
        let (j, _) = c.recv_until(|j| j.get("done").is_some() || j.get("error").is_some());
        assert!(j.get("error").is_none(), "stolen generate must complete: {j:?}");
        assert_eq!(j.get("tokens").unwrap().as_i64(), Some(24));
    }
    c.send(r#"{"op":"stats"}"#);
    let (stats, _) = c.recv_until(|j| j.get("stolen").is_some());
    assert!(
        stats.get("stolen").unwrap().as_i64().unwrap() >= 1,
        "the dead replica's queue must have been stolen: {stats:?}"
    );
}

/// A worker whose engine faults drops the router connection but keeps
/// its process (here: thread + listener) alive; the proxy reconnects
/// with backoff and the router counts the rejoin in `restarts`.
#[test]
fn dropped_conn_worker_reconnects_and_counts_restart() {
    let waddr = free_addr();
    spawn_mock_worker(&waddr, 20, Some(FailureMode::DropConn(2)));
    let front = start_router(&[waddr], true, 2);
    let mut c = Client::connect(&front);

    // admitting work trips the fault within a few cycles; the generate
    // itself may be stolen into a shed (no survivor) — irrelevant here,
    // the stats poll skips whatever frame it turns into
    c.send(r#"{"op":"generate","prompt":"q: casualty ?\n","max_tokens":64,"stream":false}"#);
    wait_for_restarts(&mut c, 1, 20);
}

/// Property test on the autoscaler core: whatever the (randomized)
/// pool telemetry looks like, every emitted action targets a slot in a
/// state that action is valid for, respects the min/max bounds, and
/// keeps the retune knobs inside the engine's accepted ranges.
#[test]
fn autoscaler_actions_always_target_valid_slots() {
    let mut rng = Pcg32::seeded(0x7ab5_0f2d);
    for trial in 0..20u32 {
        let cap = 1 + rng.below(6) as usize;
        let min = 1 + rng.below(cap as u32) as usize;
        let cfg = AutoscaleConfig {
            min_replicas: min,
            max_replicas: cap,
            idle_ticks: 1 + rng.below(4),
            dead_grace_ticks: 1 + rng.below(6),
            retune_cooldown_ticks: rng.below(4),
            ..AutoscaleConfig::default()
        };
        let mut core = AutoscaleCore::new(cfg.clone());
        let mut shed = 0u64;
        for _ in 0..400 {
            shed += rng.below(3) as u64;
            let samples: Vec<ReplicaSample> = (0..cap)
                .map(|k| {
                    let vacant = rng.below(4) == 0;
                    let dead = !vacant && rng.below(4) == 0;
                    let draining = !vacant && !dead && rng.below(4) == 0;
                    ReplicaSample {
                        replica: k,
                        vacant,
                        dead,
                        draining,
                        load: rng.below(5) as usize,
                        wait_signal_ns: rng.below(200) as u64 * 1_000_000,
                        acceptance: (rng.below(2) == 1).then(|| rng.next_f64()),
                    }
                })
                .collect();
            let occupied = samples.iter().filter(|s| !s.vacant && !s.dead).count();
            for a in core.tick(&samples, shed) {
                match a {
                    Action::ScaleUp { replica } => {
                        let s = &samples[replica];
                        assert!(s.vacant, "trial {trial}: scale-up into a held slot");
                        assert!(occupied < cfg.max_replicas, "trial {trial}: over capacity");
                    }
                    Action::Drain { replica } => {
                        let s = &samples[replica];
                        assert!(
                            !s.vacant && !s.dead && !s.draining,
                            "trial {trial}: drain of a non-routable slot"
                        );
                        assert!(occupied > cfg.min_replicas, "trial {trial}: below minimum");
                    }
                    Action::Retire { replica } => {
                        let s = &samples[replica];
                        assert!(
                            s.dead
                                || (s.draining
                                    && s.load == 0
                                    && occupied > cfg.min_replicas),
                            "trial {trial}: retire of a live slot: {s:?}"
                        );
                    }
                    Action::Reconfigure { replica, gamma, kv_bits } => {
                        let s = &samples[replica];
                        assert!(!s.vacant && !s.dead && !s.draining);
                        assert!(s.acceptance.is_some(), "trial {trial}: retune before data");
                        assert!(gamma.is_some() || kv_bits.is_some());
                        if let Some(g) = gamma {
                            assert!((1..=8).contains(&g), "trial {trial}: gamma {g}");
                        }
                        if let Some(b) = kv_bits {
                            assert!((2..=8).contains(&b), "trial {trial}: kv_bits {b}");
                        }
                    }
                }
            }
        }
    }
}

/// The real thing, end to end: a separate `qspec serve --worker --mock`
/// process, SIGKILLed mid-stream (no goodbye of any kind), then a
/// fresh process respawned on the same address. The client sees a
/// structured `replica_lost`, the router counts the rejoin, and the
/// pool serves again.
#[test]
fn two_process_worker_survives_kill9_and_respawn() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_qspec") else {
        eprintln!("transport: CARGO_BIN_EXE_qspec unset (lib-only build) — skipping");
        return;
    };
    let waddr = free_addr();
    let spawn_worker = || -> Child {
        Command::new(bin)
            .args(["serve", "--worker", waddr.as_str(), "--mock", "--mock-delay-ms", "20"])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn worker process")
    };
    let mut child = spawn_worker();
    wait_listening(&waddr);
    let front = start_router(&[waddr.clone()], false, 2);
    let mut c = Client::connect(&front);

    // healthy round trip across the process boundary
    let (text, ntok, _) = c.stream_generate(
        r#"{"op":"generate","prompt":"q: ipc ?\n","max_tokens":8,"stream":true}"#,
    );
    assert!(!text.is_empty());
    assert_eq!(ntok, 8);

    // kill -9 mid-stream: wait for the first delta so the stream is
    // provably in flight, then SIGKILL the worker process
    c.send(r#"{"op":"generate","prompt":"q: doomed ?\n","max_tokens":400,"stream":true}"#);
    let _ = c.recv_until(|j| j.get("delta").is_some());
    child.kill().expect("kill -9 worker");
    let _ = child.wait();
    let (j, _) = c.recv_until(|j| j.get("error").is_some());
    let err = j.get("error").unwrap();
    assert_eq!(err.get("code").unwrap().as_str(), Some("replica_lost"));
    assert!(err.get("retry_after_ms").is_some());

    // a fresh process on the same address: the proxy's backoff loop
    // adopts it, the router counts the restart, service resumes
    let mut child2 = spawn_worker();
    wait_for_restarts(&mut c, 1, 30);
    let (_, ntok2, _) = c.stream_generate(
        r#"{"op":"generate","prompt":"q: back ?\n","max_tokens":6,"stream":true}"#,
    );
    assert_eq!(ntok2, 6);
    let _ = child2.kill();
    let _ = child2.wait();
}
