//! Property tests on coordinator invariants using the in-crate mini
//! property-testing framework (util::check). These run without
//! artifacts — pure logic over SlotManager / acceptance / the
//! `SchedPolicy` implementations (FCFS, priority-with-aging, SJF, EDF)
//! and the `BatchCore` admission semantics layered on them (deadline
//! expiry at admission).

use std::time::Duration;

use qspec::config::SchedKind;
use qspec::coordinator::{
    build_policy, greedy_accept, BatchCore, FcfsPolicy, FinishReason, GenerationRequest,
    PriorityPolicy, Request, SamplingParams, SchedPolicy, StepEvent, AGING_TICKS_PER_LEVEL,
    MAX_PRIORITY,
};
use qspec::costmodel::{twins::Twin, CostModel};
use qspec::kvcache::SlotManager;
use qspec::util::check::check;
use qspec::util::prng::Pcg32;

const EOS: i32 = 2;

/// Random sequence of scheduler operations must preserve slot invariants:
/// pos advances exactly by committed tokens, never past max_seq, and
/// released requests return exactly the tokens committed for them.
#[test]
fn slot_manager_invariants_under_random_ops() {
    check(
        "slot-invariants",
        300,
        |r: &mut Pcg32| {
            // (batch, ops): ops encoded as random u32 stream
            let batch = r.range_inclusive(1, 8) as usize;
            let ops: Vec<u32> = (0..r.range_inclusive(5, 60)).map(|_| r.next_u32()).collect();
            (batch, ops)
        },
        |(batch, ops)| {
            let max_seq = 64usize;
            let prefill_t = 16usize;
            let gamma = 3usize;
            let mut m = SlotManager::new(*batch, max_seq, prefill_t);
            let mut next_id = 0u64;
            let mut expected: std::collections::HashMap<u64, Vec<i32>> =
                std::collections::HashMap::new();
            for &op in ops {
                match op % 3 {
                    0 => {
                        // admit if possible
                        if m.free_slots().next().is_some() {
                            let plen = 1 + (op as usize % prefill_t);
                            let prompt: Vec<i32> =
                                (0..plen).map(|j| (op as i32 + j as i32) % 50).collect();
                            let id = next_id;
                            next_id += 1;
                            let idx = m
                                .admit(id, &prompt, 4 + op as usize % 20, vec![])
                                .map_err(|e| format!("admit: {e}"))?;
                            let t0 = 10 + (op % 40) as i32;
                            m.after_prefill(idx, t0, EOS);
                            expected.insert(id, vec![t0]);
                            if m.slot(idx).pos as usize != prefill_t {
                                return Err("pos != prefill_t after prefill".into());
                            }
                        }
                    }
                    1 => {
                        // commit a random batch of tokens on an active slot
                        if let Some(idx) = m.active_slots().next() {
                            let id = m.slot(idx).req_id.unwrap();
                            let pos_before = m.slot(idx).pos;
                            let n = 1 + (op as usize % (gamma + 1));
                            let toks: Vec<i32> =
                                (0..n).map(|j| 10 + ((op as i32) + j as i32) % 40).collect();
                            let committed = m.commit(idx, &toks, EOS, gamma);
                            if committed.is_empty() {
                                return Err("commit returned empty".into());
                            }
                            expected.get_mut(&id).unwrap().extend(&committed);
                            let pos_after = m.slot(idx).pos;
                            if pos_after - pos_before != committed.len() as i32 {
                                return Err(format!(
                                    "pos advanced {} for {} commits",
                                    pos_after - pos_before,
                                    committed.len()
                                ));
                            }
                            if (pos_after as usize) > max_seq {
                                return Err("pos past max_seq".into());
                            }
                        }
                    }
                    _ => {
                        // release any done slot
                        let done: Vec<usize> = m
                            .iter()
                            .filter(|(_, s)| s.req_id.is_some() && s.done)
                            .map(|(i, _)| i)
                            .collect();
                        for idx in done {
                            let (id, toks) = m.release(idx).ok_or("release failed")?;
                            let exp = expected.remove(&id).ok_or("unknown id")?;
                            if toks != exp {
                                return Err(format!("released {toks:?} != committed {exp:?}"));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Acceptance policy: output of QSPEC == what W4A16 greedy would emit,
/// step by step, for ANY draft sequence (losslessness at the policy level).
#[test]
fn acceptance_equals_sequential_greedy() {
    check(
        "acceptance-lossless",
        500,
        |r: &mut Pcg32| {
            let g = r.range_inclusive(1, 6) as usize;
            // the verifier's greedy choices (what AR would emit)
            let verify: Vec<u32> = (0..g + 1).map(|_| r.below(16)).collect();
            let drafts: Vec<u32> = (0..g).map(|_| r.below(16)).collect();
            (drafts, verify)
        },
        |(drafts, verify)| {
            let d: Vec<i32> = drafts.iter().map(|&x| x as i32).collect();
            let v: Vec<i32> = verify.iter().map(|&x| x as i32).collect();
            let dec = greedy_accept(&d, &v);
            // sequential greedy under the same verifier function emits
            // v[0..] until it diverges from drafts; committed must be a
            // prefix of the verifier's own choices at every position
            for (j, &t) in dec.committed.iter().enumerate() {
                if t != v[j] && (j >= d.len() || d[j] != t) {
                    return Err(format!("committed[{j}]={t} matches neither"));
                }
                // token j is either the draft (== verify) or the verify fix
                if j < dec.accepted {
                    if t != v[j] {
                        return Err("accepted token differs from verifier".into());
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// SchedPolicy properties
// ---------------------------------------------------------------------------

/// The deadline an op word encodes: `None` a quarter of the time, else
/// a multiple of 10 seconds. Coarse spacing matters: the policy orders
/// on absolute instants (`arrival + ms`) while the model orders on the
/// ms values, and the two agree as long as the spacing dwarfs the
/// construction jitter between pushes.
fn op_deadline_ms(op: u32) -> Option<u64> {
    match op / 128 % 4 {
        0 => None,
        k => Some(((op / 512 % 64) as u64 + 1) * 10_000 * k as u64),
    }
}

/// Decode one op word into a queued request's QoS shape: priority in
/// 0..=3, max_tokens in 4..=35, deadline per [`op_deadline_ms`].
fn req_from_op(id: u64, op: u32) -> Request {
    let priority = (op % 4) as u8;
    let max_tokens = 4 + (op / 4 % 32) as usize;
    Request::with_qos(
        id,
        vec![1],
        SamplingParams::greedy(max_tokens),
        priority,
        op_deadline_ms(op),
    )
}

/// Model entry mirroring what a policy knows about a queued request.
#[derive(Clone, Debug)]
struct Model {
    id: u64,
    seq: u64,
    priority: u8,
    max_tokens: usize,
    deadline_ms: Option<u64>,
}

/// The id the model expects `pop_next` to return for each policy.
fn model_next(kind: SchedKind, q: &[Model]) -> Option<u64> {
    let pick = match kind {
        SchedKind::Fcfs => q.iter().min_by_key(|m| m.seq),
        SchedKind::Priority => {
            // no on_tick in the random-ops property -> no aging applies
            q.iter().min_by_key(|m| (MAX_PRIORITY - m.priority, m.seq))
        }
        SchedKind::Sjf => q.iter().min_by_key(|m| (m.max_tokens, m.seq)),
        SchedKind::Edf => q
            .iter()
            .min_by_key(|m| (m.deadline_ms.is_none(), m.deadline_ms.unwrap_or(0), m.seq)),
    };
    pick.map(|m| m.id)
}

/// Every policy pops exactly the request its ordering rule names, under
/// random interleavings of push / pop / remove — and `remove` never
/// disturbs the relative order of what stays queued.
#[test]
fn policy_ordering_properties_under_random_ops() {
    for kind in SchedKind::ALL {
        check(
            kind.label(),
            200,
            |r: &mut Pcg32| {
                let ops: Vec<u32> =
                    (0..r.range_inclusive(1, 60)).map(|_| r.next_u32()).collect();
                ops
            },
            |ops| {
                let mut q = build_policy(kind);
                let mut model: Vec<Model> = Vec::new();
                let mut next_id = 0u64;
                let mut next_seq = 0u64;
                for &op in ops {
                    match op % 4 {
                        // push twice as often as each other op so the
                        // queue actually grows
                        0 | 1 => {
                            let r = req_from_op(next_id, op);
                            model.push(Model {
                                id: r.id,
                                seq: next_seq,
                                priority: r.priority,
                                max_tokens: r.params.max_tokens,
                                deadline_ms: op_deadline_ms(op),
                            });
                            q.push(r);
                            next_id += 1;
                            next_seq += 1;
                        }
                        2 => {
                            let want = model_next(kind, &model);
                            let got = q.pop_next().map(|r| r.id);
                            if got != want {
                                return Err(format!("pop {got:?} want {want:?}"));
                            }
                            if let Some(id) = got {
                                model.retain(|m| m.id != id);
                            }
                        }
                        _ => {
                            // remove a random queued id (or a bogus one)
                            if model.is_empty() {
                                if q.remove(9999).is_some() {
                                    return Err("removed nonexistent id".into());
                                }
                            } else {
                                let victim = model[op as usize % model.len()].id;
                                let got = q.remove(victim).map(|r| r.id);
                                if got != Some(victim) {
                                    return Err(format!("remove {victim} got {got:?}"));
                                }
                                model.retain(|m| m.id != victim);
                            }
                        }
                    }
                    // peek always agrees with what the next pop would be
                    let want = model_next(kind, &model);
                    if q.peek_next().map(|r| r.id) != want {
                        return Err(format!("peek disagrees with model ({})", kind.label()));
                    }
                    if q.len() != model.len() {
                        return Err("length mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }
}

/// Aging: a background request stuck behind a continuous stream of
/// critical arrivals is still admitted within a bounded number of
/// scheduling rounds (it gains one effective level per aging window,
/// then wins the FCFS tie inside the top class).
#[test]
fn aging_eventually_admits_starved_low_priority() {
    let mut q = PriorityPolicy::new();
    q.push(req_with_priority(0, 0));
    let bound = MAX_PRIORITY as u64 * AGING_TICKS_PER_LEVEL + 2;
    let mut admitted_at = None;
    for round in 0..bound {
        // adversarial arrival pattern: a fresh critical request every round
        q.push(req_with_priority(1 + round, MAX_PRIORITY));
        q.on_tick();
        let popped = q.pop_next().expect("queue nonempty");
        if popped.id == 0 {
            admitted_at = Some(round);
            break;
        }
    }
    let round = admitted_at.expect("aging failed to admit the starved request");
    assert!(
        round >= AGING_TICKS_PER_LEVEL,
        "admitted suspiciously early (round {round}): aging should take effect gradually"
    );
}

fn req_with_priority(id: u64, priority: u8) -> Request {
    Request::with_qos(id, vec![1], SamplingParams::greedy(4), priority, None)
}

/// Cancellation (`remove`) under every policy: the drain order with a
/// victim removed equals the full drain order minus the victim.
#[test]
fn remove_preserves_order_under_every_policy() {
    for kind in SchedKind::ALL {
        check(
            "remove-order",
            100,
            |r: &mut Pcg32| {
                let ops: Vec<u32> =
                    (0..r.range_inclusive(2, 24)).map(|_| r.next_u32()).collect();
                let victim = r.below(24) as usize;
                (ops, victim)
            },
            |(ops, victim)| {
                // the same Request values (same arrival instants) into
                // two instances of the same policy
                let reqs: Vec<Request> = ops
                    .iter()
                    .enumerate()
                    .map(|(i, &op)| req_from_op(i as u64, op))
                    .collect();
                let mut full = build_policy(kind);
                let mut pruned = build_policy(kind);
                for r in &reqs {
                    full.push(r.clone());
                    pruned.push(r.clone());
                }
                let victim_id = (*victim % reqs.len()) as u64;
                let removed = pruned.remove(victim_id).ok_or("victim not removable")?;
                if removed.id != victim_id {
                    return Err("remove returned the wrong request".into());
                }
                let full_order: Vec<u64> =
                    std::iter::from_fn(|| full.pop_next()).map(|r| r.id).collect();
                let pruned_order: Vec<u64> =
                    std::iter::from_fn(|| pruned.pop_next()).map(|r| r.id).collect();
                let expect: Vec<u64> =
                    full_order.iter().copied().filter(|&id| id != victim_id).collect();
                if pruned_order != expect {
                    return Err(format!(
                        "{}: order after remove {pruned_order:?} != {expect:?}",
                        kind.label()
                    ));
                }
                Ok(())
            },
        );
    }
}

/// EDF + BatchCore: an already-expired deadline is never admitted to a
/// slot — it terminates with `deadline_exceeded` at admission time and
/// the slot goes to live work instead.
#[test]
fn edf_never_admits_an_already_expired_deadline() {
    let mut core = BatchCore::new(
        SlotManager::new(1, 64, 16),
        CostModel::new(Twin::lookup("llama2-7b")),
    );
    core.set_policy(build_policy(SchedKind::Edf));
    let doomed = core.submit_request(
        GenerationRequest::greedy(vec![1, 2], 8).with_deadline_ms(1),
    );
    let live = core.submit_request(
        GenerationRequest::greedy(vec![3, 4], 8).with_deadline_ms(60_000),
    );
    std::thread::sleep(Duration::from_millis(5));
    let mut out = Vec::new();
    let pb = core.admit_batch(&mut out).unwrap();
    // EDF pops the doomed request first (earliest deadline), expires it
    // without a slot, then admits the live one into the freed capacity
    let admitted = pb.expect("live request admitted");
    assert_eq!(admitted.admitted.len(), 1);
    assert_eq!(admitted.admitted[0].1.id, live);
    let f = out
        .into_iter()
        .filter_map(StepEvent::into_done)
        .next()
        .expect("expired terminal event");
    assert_eq!(f.id, doomed);
    assert_eq!(f.finish_reason, FinishReason::DeadlineExceeded);
    assert!(f.tokens.is_empty());
    assert_eq!(core.metrics.deadline_expired, 1);
    // the single slot went to the live request, not the expired one
    assert_eq!(core.slots.active_count(), 1);
    assert_eq!(core.slots.slot(admitted.admitted[0].0).req_id, Some(live));
}

/// FCFS-specific regression: pops are exactly pushes, in order, under
/// random interleaving (the original queue property, kept verbatim
/// against the trait API).
#[test]
fn fcfs_queue_order_property() {
    check(
        "fcfs-order",
        300,
        |r: &mut Pcg32| {
            let ops: Vec<u32> = (0..r.range_inclusive(1, 50)).map(|_| r.next_u32()).collect();
            ops
        },
        |ops| {
            // ids are assigned by the engine core; the queue is pure
            // ordering, so the model assigns them here
            let mut q = FcfsPolicy::new();
            let mut pushed = std::collections::VecDeque::new();
            let mut next_id = 0u64;
            for &op in ops {
                if op % 2 == 0 {
                    let id = next_id;
                    next_id += 1;
                    q.push(Request::new(id, vec![op as i32], 4));
                    pushed.push_back(id);
                } else if let Some(r) = q.pop_next() {
                    let want = pushed.pop_front().ok_or("pop from empty model")?;
                    if r.id != want {
                        return Err(format!("popped {} want {want}", r.id));
                    }
                }
                // peek always reports the same request the next pop returns
                if let (Some(head), Some(&want)) = (q.peek_next(), pushed.front()) {
                    if head.id != want {
                        return Err(format!("peek {} want {want}", head.id));
                    }
                }
            }
            if q.len() != pushed.len() {
                return Err("length mismatch".into());
            }
            Ok(())
        },
    );
}
