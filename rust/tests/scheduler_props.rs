//! Property tests on coordinator invariants (DESIGN.md §7) using the
//! in-crate mini property-testing framework (util::check). These run
//! without artifacts — pure logic over SlotManager / acceptance / queue.

use qspec::coordinator::{greedy_accept, FcfsQueue, Request};
use qspec::kvcache::SlotManager;
use qspec::util::check::check;
use qspec::util::prng::Pcg32;

const EOS: i32 = 2;

/// Random sequence of scheduler operations must preserve slot invariants:
/// pos advances exactly by committed tokens, never past max_seq, and
/// released requests return exactly the tokens committed for them.
#[test]
fn slot_manager_invariants_under_random_ops() {
    check(
        "slot-invariants",
        300,
        |r: &mut Pcg32| {
            // (batch, ops): ops encoded as random u32 stream
            let batch = r.range_inclusive(1, 8) as usize;
            let ops: Vec<u32> = (0..r.range_inclusive(5, 60)).map(|_| r.next_u32()).collect();
            (batch, ops)
        },
        |(batch, ops)| {
            let max_seq = 64usize;
            let prefill_t = 16usize;
            let gamma = 3usize;
            let mut m = SlotManager::new(*batch, max_seq, prefill_t);
            let mut next_id = 0u64;
            let mut expected: std::collections::HashMap<u64, Vec<i32>> =
                std::collections::HashMap::new();
            for &op in ops {
                match op % 3 {
                    0 => {
                        // admit if possible
                        if !m.free_slots().is_empty() {
                            let plen = 1 + (op as usize % prefill_t);
                            let id = next_id;
                            next_id += 1;
                            let idx = m
                                .admit(id, plen, 4 + op as usize % 20, vec![])
                                .map_err(|e| format!("admit: {e}"))?;
                            let t0 = 10 + (op % 40) as i32;
                            m.after_prefill(idx, t0, EOS);
                            expected.insert(id, vec![t0]);
                            if m.slot(idx).pos as usize != prefill_t {
                                return Err("pos != prefill_t after prefill".into());
                            }
                        }
                    }
                    1 => {
                        // commit a random batch of tokens on an active slot
                        let active = m.active_slots();
                        if let Some(&idx) = active.first() {
                            let id = m.slot(idx).req_id.unwrap();
                            let pos_before = m.slot(idx).pos;
                            let n = 1 + (op as usize % (gamma + 1));
                            let toks: Vec<i32> =
                                (0..n).map(|j| 10 + ((op as i32) + j as i32) % 40).collect();
                            let committed = m.commit(idx, &toks, EOS, gamma);
                            if committed.is_empty() {
                                return Err("commit returned empty".into());
                            }
                            expected.get_mut(&id).unwrap().extend(&committed);
                            let pos_after = m.slot(idx).pos;
                            if pos_after - pos_before != committed.len() as i32 {
                                return Err(format!(
                                    "pos advanced {} for {} commits",
                                    pos_after - pos_before,
                                    committed.len()
                                ));
                            }
                            if (pos_after as usize) > max_seq {
                                return Err("pos past max_seq".into());
                            }
                        }
                    }
                    _ => {
                        // release any done slot
                        let done: Vec<usize> = m
                            .iter()
                            .filter(|(_, s)| s.req_id.is_some() && s.done)
                            .map(|(i, _)| i)
                            .collect();
                        for idx in done {
                            let (id, toks) = m.release(idx).ok_or("release failed")?;
                            let exp = expected.remove(&id).ok_or("unknown id")?;
                            if toks != exp {
                                return Err(format!("released {toks:?} != committed {exp:?}"));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Acceptance policy: output of QSPEC == what W4A16 greedy would emit,
/// step by step, for ANY draft sequence (losslessness at the policy level).
#[test]
fn acceptance_equals_sequential_greedy() {
    check(
        "acceptance-lossless",
        500,
        |r: &mut Pcg32| {
            let g = r.range_inclusive(1, 6) as usize;
            // the verifier's greedy choices (what AR would emit)
            let verify: Vec<u32> = (0..g + 1).map(|_| r.below(16)).collect();
            let drafts: Vec<u32> = (0..g).map(|_| r.below(16)).collect();
            (drafts, verify)
        },
        |(drafts, verify)| {
            let d: Vec<i32> = drafts.iter().map(|&x| x as i32).collect();
            let v: Vec<i32> = verify.iter().map(|&x| x as i32).collect();
            let dec = greedy_accept(&d, &v);
            // sequential greedy under the same verifier function emits
            // v[0..] until it diverges from drafts; committed must be a
            // prefix of the verifier's own choices at every position
            for (j, &t) in dec.committed.iter().enumerate() {
                if t != v[j] && (j >= d.len() || d[j] != t) {
                    return Err(format!("committed[{j}]={t} matches neither"));
                }
                // token j is either the draft (== verify) or the verify fix
                if j < dec.accepted {
                    if t != v[j] {
                        return Err("accepted token differs from verifier".into());
                    }
                }
            }
            Ok(())
        },
    );
}

/// FCFS queue: pops are exactly pushes, in order, under random interleaving.
#[test]
fn fcfs_queue_order_property() {
    check(
        "fcfs-order",
        300,
        |r: &mut Pcg32| {
            let ops: Vec<u32> = (0..r.range_inclusive(1, 50)).map(|_| r.next_u32()).collect();
            ops
        },
        |ops| {
            // ids are assigned by the engine core; the queue is pure
            // ordering, so the model assigns them here
            let mut q = FcfsQueue::new();
            let mut pushed = std::collections::VecDeque::new();
            let mut next_id = 0u64;
            for &op in ops {
                if op % 2 == 0 {
                    let id = next_id;
                    next_id += 1;
                    q.push_request(Request::new(id, vec![op as i32], 4));
                    pushed.push_back(id);
                } else if let Some(r) = q.pop() {
                    let want = pushed.pop_front().ok_or("pop from empty model")?;
                    if r.id != want {
                        return Err(format!("popped {} want {want}", r.id));
                    }
                }
                // peek always reports the same request the next pop returns
                if let (Some(head), Some(&want)) = (q.peek(), pushed.front()) {
                    if head.id != want {
                        return Err(format!("peek {} want {want}", head.id));
                    }
                }
            }
            if q.len() != pushed.len() {
                return Err("length mismatch".into());
            }
            Ok(())
        },
    );
}
