//! Property tests for `coordinator::acceptance` — the paper's
//! losslessness invariant, checked under random draft/verify streams
//! with the in-tree shrinking property harness (`util::check`).
//!
//! What must hold for `greedy_accept(drafts, verify_argmax)`:
//!   1. it returns the longest matching prefix plus exactly one
//!      correction/bonus token from the verifier;
//!   2. it never reads the verifier stream past the first mismatch —
//!      the tail beyond position `accepted` cannot influence the
//!      decision (speculative decoding may not leak unverified state);
//!   3. driven in a loop against a deterministic verifier, the
//!      committed token stream equals the verifier's own greedy
//!      rollout exactly, whatever the drafts were (losslessness: the
//!      draft phase can only change *speed*, never *output*).
//!
//! And for `stochastic_accept(drafts, q, p, ...)` — the temperature>0
//! analogue (Leviathan et al.), checked empirically with seeded
//! samplers (every test is deterministic):
//!   4. draft token `d` is accepted with probability `min(1, p_d/q_d)`;
//!   5. a rejection resamples from the normalized residual
//!      `norm(max(0, p - q))` — never the rejected token itself;
//!   6. end-to-end, the committed stream is distributed exactly as a
//!      verifier-only rollout (distribution-losslessness: whatever q
//!      is, speculation changes speed, never the distribution).

use qspec::coordinator::{
    greedy_accept, stochastic_accept, stochastic_tree_accept, SamplingParams,
};
use qspec::sampler::Sampler;
use qspec::tree::TokenTree;
use qspec::util::check::check;
use qspec::util::prng::Pcg32;

/// Small vocab so random drafts agree with the verifier often enough
/// to exercise multi-token acceptance, not just instant rejection.
const VOCAB: u32 = 8;

fn gen_streams(r: &mut Pcg32) -> (Vec<u32>, Vec<u32>) {
    let g = r.range_inclusive(1, 6) as usize;
    let drafts: Vec<u32> = (0..g).map(|_| r.below(VOCAB)).collect();
    let verify: Vec<u32> = (0..g + 1).map(|_| r.below(VOCAB)).collect();
    (drafts, verify)
}

fn to_i32(v: &[u32]) -> Vec<i32> {
    v.iter().map(|&x| x as i32).collect()
}

/// The longest prefix where draft and verifier agree.
fn matching_prefix(drafts: &[i32], verify: &[i32]) -> usize {
    drafts.iter().zip(verify).take_while(|(d, v)| d == v).count()
}

#[test]
fn accepts_longest_matching_prefix_plus_one_correction() {
    check("accept-prefix", 2000, gen_streams, |(drafts, verify)| {
        let d = to_i32(drafts);
        let v = to_i32(verify);
        let dec = greedy_accept(&d, &v);
        let k = matching_prefix(&d, &v);
        if dec.accepted != k {
            return Err(format!("accepted {} != longest matching prefix {k}", dec.accepted));
        }
        // exactly the prefix plus one token, and that token is the
        // verifier's at the rejection/bonus position
        if dec.committed.len() != k + 1 {
            return Err(format!("committed {} tokens != {k} + 1", dec.committed.len()));
        }
        if dec.committed[..k] != d[..k] {
            return Err("committed prefix != accepted drafts".into());
        }
        if dec.committed[k] != v[k] {
            return Err("correction token is not the verifier's".into());
        }
        Ok(())
    });
}

#[test]
fn never_reads_past_the_first_mismatch() {
    check("accept-no-lookahead", 2000, gen_streams, |(drafts, verify)| {
        let d = to_i32(drafts);
        let v = to_i32(verify);
        let dec = greedy_accept(&d, &v);
        // poison everything after the decision point: the verifier
        // positions beyond `accepted` correspond to unverified state
        // and must not be able to change the outcome
        let mut poisoned = v.clone();
        for t in poisoned.iter_mut().skip(dec.accepted + 1) {
            *t = -999;
        }
        let dec2 = greedy_accept(&d, &poisoned);
        if dec2 != dec {
            return Err(format!("decision depends on the unread tail: {dec:?} vs {dec2:?}"));
        }
        Ok(())
    });
}

/// A deterministic toy verifier: its argmax after any context is a
/// hash of that context. Stands in for "the W4A16 model" so the
/// rollout-equality invariant is checkable without artifacts.
fn verifier_next(context: &[i32]) -> i32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in context {
        h ^= t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % VOCAB as u64) as i32
}

#[test]
fn committed_stream_equals_verifier_rollout_regardless_of_drafts() {
    // the losslessness invariant (paper Sec. 3.1): run cycles of
    // arbitrary drafting + greedy_accept against the toy verifier and
    // the committed stream must equal the verifier's own pure-AR
    // rollout of the same length
    check(
        "accept-lossless-rollout",
        300,
        |r: &mut Pcg32| {
            let gamma = r.range_inclusive(1, 5);
            let cycles = r.range_inclusive(1, 8);
            // one u32 per potential draft position: the drafting policy
            // (sometimes the true next token, sometimes garbage)
            let raw: Vec<u32> = (0..(cycles * gamma) as usize).map(|_| r.next_u32()).collect();
            (gamma, raw)
        },
        |(gamma, raw)| {
            let gamma = (*gamma).max(1) as usize;
            let mut committed: Vec<i32> = vec![verifier_next(&[])]; // "prefill" token
            let mut draws = raw.iter().copied().peekable();
            while draws.peek().is_some() && committed.len() <= raw.len() {
                // draft gamma tokens: ~half the time the draft guesses
                // the verifier's true continuation, otherwise garbage
                let mut drafts = Vec::with_capacity(gamma);
                let mut ctx = committed.clone();
                for _ in 0..gamma {
                    let u = match draws.next() {
                        Some(u) => u,
                        None => break,
                    };
                    let truth = verifier_next(&ctx);
                    let t = if u % 2 == 0 { truth } else { (u % VOCAB) as i32 };
                    drafts.push(t);
                    ctx.push(t);
                }
                if drafts.is_empty() {
                    break;
                }
                // the verifier scores prefix + drafts[..j] at position j
                let mut verify = Vec::with_capacity(drafts.len() + 1);
                let mut vctx = committed.clone();
                for &t in &drafts {
                    verify.push(verifier_next(&vctx));
                    vctx.push(t);
                }
                verify.push(verifier_next(&vctx));
                let dec = greedy_accept(&drafts, &verify);
                if dec.committed.is_empty() || dec.committed.len() > drafts.len() + 1 {
                    return Err("commit bounds violated".into());
                }
                committed.extend(dec.committed);
            }
            // pure-AR rollout of the same length must match exactly
            let mut ar = vec![verifier_next(&[])];
            while ar.len() < committed.len() {
                ar.push(verifier_next(&ar));
            }
            if ar != committed {
                return Err(format!(
                    "speculative stream diverged from the verifier's rollout:\n  spec {committed:?}\n  ar   {ar:?}"
                ));
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// Stochastic acceptance (temperature > 0) — properties 4–6.
//
// These are statistical tests over *seeded* samplers: every run draws
// the same trials, so they are deterministic in CI. Tolerances are set
// several standard errors above the expected sampling noise.
// ---------------------------------------------------------------------------

const SV: usize = VOCAB as usize;

fn sampler(seed: u64) -> Sampler {
    Sampler::new(&SamplingParams { temperature: 1.0, seed, ..SamplingParams::default() })
}

/// Deterministic toy *verifier* logits over the small vocab — the
/// stochastic analogue of `verifier_next` (a distribution per context
/// token instead of a single argmax).
fn p_logits(ctx: i32) -> Vec<f32> {
    logits_from(ctx as u64 ^ 0x9e37_79b9_7f4a_7c15)
}

/// Deterministic toy *draft* logits: the verifier's logits plus a
/// large context-keyed perturbation, so q is measurably wrong — the
/// acceptance rule has to do real correcting for property 6 to hold.
fn q_logits(ctx: i32) -> Vec<f32> {
    let mut l = p_logits(ctx);
    let noise = logits_from(ctx as u64 ^ 0x517c_c1b7_2722_0a95);
    for (a, b) in l.iter_mut().zip(noise) {
        *a += 0.8 * b;
    }
    l
}

fn logits_from(key: u64) -> Vec<f32> {
    let mut r = Pcg32::new(key, 7);
    (0..SV).map(|_| 4.0 * (r.next_f64() as f32) - 2.0).collect()
}

/// q (one row) and p (two rows: position 0 plus the bonus row scored
/// after the draft token) for a single-draft `stochastic_accept` call.
fn single_draft_qp(ctx: i32, d: usize) -> (Vec<f32>, Vec<f32>) {
    let s0 = sampler(0);
    let q = s0.probs(&q_logits(ctx));
    let mut p = s0.probs(&p_logits(ctx));
    p.extend_from_slice(&s0.probs(&p_logits(d as i32)));
    (q, p)
}

/// Property 4: a pinned draft token `d` is accepted with empirical
/// frequency `min(1, p_d / q_d)`.
#[test]
fn stochastic_acceptance_frequency_is_min_one_p_over_q() {
    for d in 0..SV {
        let (q, p) = single_draft_qp(5, d);
        let expect = (p[d] as f64 / q[d] as f64).min(1.0);
        let n = 20_000u64;
        let mut hits = 0u64;
        for t in 0..n {
            let mut s = sampler(1_000 + t * (SV as u64) + d as u64);
            let dec = stochastic_accept(&[d as i32], &q, &p, SV, &mut s);
            if dec.accepted == 1 {
                hits += 1;
            }
        }
        let freq = hits as f64 / n as f64;
        assert!(
            (freq - expect).abs() < 0.02,
            "draft {d}: empirical acceptance {freq:.4} vs min(1, p/q) = {expect:.4}"
        );
    }
}

/// Property 5: on rejection, the correction token is distributed as
/// the normalized residual `norm(max(0, p - q))` — and the rejected
/// token itself (whose residual is <= 0 by construction of rejection
/// being possible) is never re-committed.
#[test]
fn rejection_resamples_from_the_normalized_residual() {
    let s0 = sampler(0);
    let q0 = s0.probs(&q_logits(9));
    let p0 = s0.probs(&p_logits(9));
    // the draft token with the largest q-overshoot rejects most often
    let d = (0..SV)
        .max_by(|&a, &b| (q0[a] - p0[a]).partial_cmp(&(q0[b] - p0[b])).unwrap())
        .unwrap();
    assert!(q0[d] > p0[d], "test setup: chosen draft must be rejectable");
    let (q, p) = single_draft_qp(9, d);
    let resid: Vec<f64> = (0..SV).map(|v| ((p[v] - q[v]) as f64).max(0.0)).collect();
    let z: f64 = resid.iter().sum();
    assert!(z > 1e-6, "test setup: residual must be nonzero");

    let mut hist = vec![0u64; SV];
    let mut rejects = 0u64;
    for t in 0..40_000u64 {
        let mut s = sampler(77_000 + t);
        let dec = stochastic_accept(&[d as i32], &q, &p, SV, &mut s);
        if dec.accepted == 0 {
            rejects += 1;
            hist[dec.committed[0] as usize] += 1;
        }
    }
    assert!(rejects > 4_000, "rejection path barely exercised: {rejects} rejects");
    assert_eq!(hist[d], 0, "rejected token must not be resampled");
    let tv: f64 = (0..SV)
        .map(|v| (hist[v] as f64 / rejects as f64 - resid[v] / z).abs())
        .sum::<f64>()
        / 2.0;
    assert!(tv < 0.025, "residual TV distance {tv:.4} too large");
}

/// One full speculative rollout with the toy models, mirroring what
/// the engines' stochastic cycles do: sample gamma drafts from q
/// sequentially, score gamma+1 verifier rows, stochastic-accept.
fn spec_rollout(seed: u64, len: usize, gamma: usize) -> Vec<i32> {
    let mut s = sampler(seed);
    let p0 = s.probs(&p_logits(0));
    let mut committed = vec![s.sample_probs(&p0) as i32];
    while committed.len() < len {
        let pending = *committed.last().unwrap();
        let mut drafts = Vec::with_capacity(gamma);
        let mut q = Vec::with_capacity(gamma * SV);
        let mut cur = pending;
        for _ in 0..gamma {
            let qp = s.probs(&q_logits(cur));
            let d = s.sample_probs(&qp) as i32;
            q.extend_from_slice(&qp);
            drafts.push(d);
            cur = d;
        }
        let mut p = Vec::with_capacity((gamma + 1) * SV);
        let mut prev = pending;
        for j in 0..=gamma {
            p.extend_from_slice(&s.probs(&p_logits(prev)));
            if j < gamma {
                prev = drafts[j];
            }
        }
        let dec = stochastic_accept(&drafts, &q, &p, SV, &mut s);
        committed.extend(dec.committed);
    }
    committed.truncate(len);
    committed
}

/// Property 6: the marginal distribution of the L-th committed token
/// under speculative decoding equals the *exact* verifier-chain
/// marginal (computed by powering the 8x8 transition matrix), while a
/// draft-only rollout measurably does not — i.e. `stochastic_accept`
/// is doing the correcting, and the correction is complete.
/// Exact verifier-chain marginal of token `len - 1` by powering the
/// 8x8 transition matrix built from `s0.probs` — so any truncation
/// knobs on `s0` (v1.7 top-k/top-p) shape the exact answer the same
/// way they shape every row the rollouts sample from.
fn exact_p_marginal(s0: &Sampler, len: usize) -> Vec<f64> {
    let rows: Vec<Vec<f32>> = (0..SV).map(|c| s0.probs(&p_logits(c as i32))).collect();
    let mut exact: Vec<f64> = s0.probs(&p_logits(0)).iter().map(|&x| x as f64).collect();
    for _ in 1..len {
        let mut next = vec![0f64; SV];
        for a in 0..SV {
            for b in 0..SV {
                next[b] += exact[a] * rows[a][b] as f64;
            }
        }
        exact = next;
    }
    exact
}

#[test]
fn committed_stream_is_distributed_as_verifier_rollout() {
    const LEN: usize = 4;
    const TRIALS: u64 = 8_000;

    // exact verifier marginal of token LEN-1 via the transition matrix
    let exact = exact_p_marginal(&sampler(0), LEN);

    let tv_to_exact = |hist: &[u64]| -> f64 {
        let n: u64 = hist.iter().sum();
        (0..SV)
            .map(|v| (hist[v] as f64 / n as f64 - exact[v]).abs())
            .sum::<f64>()
            / 2.0
    };

    // speculative rollouts, two different gammas
    for gamma in [2usize, 4] {
        let mut hist = vec![0u64; SV];
        for t in 0..TRIALS {
            let toks = spec_rollout(500_000 + t, LEN, gamma);
            hist[toks[LEN - 1] as usize] += 1;
        }
        let tv = tv_to_exact(&hist);
        assert!(
            tv < 0.03,
            "gamma {gamma}: spec marginal TV {tv:.4} from exact verifier marginal"
        );
    }

    // power check: a draft-only (q) rollout must be measurably off,
    // otherwise this test could not detect a broken acceptance rule
    let mut qhist = vec![0u64; SV];
    for t in 0..TRIALS {
        let mut s = sampler(900_000 + t);
        let mut ctx = 0i32;
        let mut last = 0i32;
        for _ in 0..LEN {
            let qp = s.probs(&q_logits(ctx));
            last = s.sample_probs(&qp) as i32;
            ctx = last;
        }
        qhist[last as usize] += 1;
    }
    let qtv = tv_to_exact(&qhist);
    assert!(
        qtv > 0.05,
        "draft-only TV {qtv:.4} too close to the verifier marginal — test has no power"
    );
}

// ---------------------------------------------------------------------------
// Tree acceptance (v1.7) — the SpecInfer-style recursive multi-branch
// rule, end to end. Same toy models, same exact-marginal oracle; the
// rollout now drafts a token *tree* per cycle.
// ---------------------------------------------------------------------------

/// A sampler with the v1.7 truncation knobs armed (top-k 5 of 8 +
/// nucleus 0.9): both q and p rows come out truncated-renormalized, so
/// the accept rule runs entirely on the truncated support.
fn tsampler(seed: u64) -> Sampler {
    Sampler::new(&SamplingParams {
        temperature: 1.0,
        seed,
        top_k: 5,
        top_p: 0.9,
        ..SamplingParams::default()
    })
}

/// One full tree-speculative rollout with the toy models, mirroring
/// the TreeSpec engine's stochastic cycle: each level draws `width`
/// i.i.d. candidates from the draft row (first draw = principal
/// chain), the verifier scores the principal chain, the tree-masked
/// rows (when `tree_rows`) are the first-order toy LM's row keyed by
/// each node's token, and `stochastic_tree_accept` commits a root
/// path.
fn tree_rollout(
    seed: u64,
    len: usize,
    width: usize,
    depth: usize,
    tree_rows: bool,
    truncated: bool,
) -> Vec<i32> {
    let mut s = if truncated { tsampler(seed) } else { sampler(seed) };
    let p0 = s.probs(&p_logits(0));
    let mut committed = vec![s.sample_probs(&p0) as i32];
    while committed.len() < len {
        let pending = *committed.last().unwrap();
        let mut tree = TokenTree::new(width, depth);
        let mut q = Vec::with_capacity(depth * SV);
        let mut cur = pending;
        for _ in 0..depth {
            let qp = s.probs(&q_logits(cur));
            let mut cands = Vec::with_capacity(width);
            for _ in 0..width {
                let d = s.sample_probs(&qp);
                cands.push((d as i32, qp[d]));
            }
            q.extend_from_slice(&qp);
            cur = cands[0].0;
            tree.push_level(&cands);
        }
        let mut p = Vec::with_capacity((depth + 1) * SV);
        let mut prev = pending;
        for j in 0..=depth {
            p.extend_from_slice(&s.probs(&p_logits(prev)));
            if j < depth {
                prev = tree.level(j)[0].token;
            }
        }
        let tp: Vec<f32> =
            tree.nodes().iter().flat_map(|n| s.probs(&p_logits(n.token))).collect();
        let dec = stochastic_tree_accept(
            &tree,
            &q,
            &p,
            if tree_rows { Some(&tp) } else { None },
            SV,
            &mut s,
        );
        committed.extend(dec.committed);
    }
    committed.truncate(len);
    committed
}

/// v1.7 property: the marginal of the L-th committed token under tree
/// speculation equals the exact verifier-chain marginal for every
/// (width, depth) shape — recursive multi-branch rejection is
/// distribution-lossless, sibling rescues and all. Both the
/// tree-masked-rows path (sibling bonus) and its `None` fallback are
/// covered.
#[test]
fn tree_committed_stream_is_distributed_as_verifier_rollout() {
    const LEN: usize = 4;
    const TRIALS: u64 = 8_000;
    let exact = exact_p_marginal(&sampler(0), LEN);
    for (width, depth, tree_rows) in
        [(2usize, 2usize, true), (2, 4, false), (3, 2, false), (3, 4, true)]
    {
        let mut hist = vec![0u64; SV];
        for t in 0..TRIALS {
            let toks = tree_rollout(700_000 + t, LEN, width, depth, tree_rows, false);
            hist[toks[LEN - 1] as usize] += 1;
        }
        let tv: f64 = (0..SV)
            .map(|v| (hist[v] as f64 / TRIALS as f64 - exact[v]).abs())
            .sum::<f64>()
            / 2.0;
        assert!(
            tv < 0.03,
            "width {width} depth {depth} (tree rows {tree_rows}): \
             tree marginal TV {tv:.4} from exact verifier marginal"
        );
    }
}

/// v1.7 satellite: truncation stays lossless under tree speculation.
/// With top-k/top-p armed, every q and p row is truncated-renormalized
/// by the same rule before any accept draw, so the committed stream
/// must be distributed as the *truncated* verifier chain — which is
/// measurably different from the untruncated one (the power check).
#[test]
fn truncated_tree_stream_matches_truncated_verifier_marginal() {
    const LEN: usize = 4;
    const TRIALS: u64 = 8_000;
    let exact = exact_p_marginal(&tsampler(0), LEN);
    let mut hist = vec![0u64; SV];
    for t in 0..TRIALS {
        let toks = tree_rollout(800_000 + t, LEN, 2, 3, true, true);
        hist[toks[LEN - 1] as usize] += 1;
    }
    let tv: f64 = (0..SV)
        .map(|v| (hist[v] as f64 / TRIALS as f64 - exact[v]).abs())
        .sum::<f64>()
        / 2.0;
    assert!(tv < 0.03, "truncated tree marginal TV {tv:.4} from truncated verifier marginal");

    // power: truncation must actually move the target (else this test
    // proves nothing beyond the untruncated one). Exact-vs-exact, so
    // the check is deterministic.
    let full = exact_p_marginal(&sampler(0), LEN);
    let shift: f64 =
        (0..SV).map(|v| (exact[v] - full[v]).abs()).sum::<f64>() / 2.0;
    assert!(shift > 1e-3, "truncation barely shifts the toy marginal ({shift:.5})");
}
