//! Property tests for `coordinator::acceptance` — the paper's
//! losslessness invariant, checked under random draft/verify streams
//! with the in-tree shrinking property harness (`util::check`).
//!
//! What must hold for `greedy_accept(drafts, verify_argmax)`:
//!   1. it returns the longest matching prefix plus exactly one
//!      correction/bonus token from the verifier;
//!   2. it never reads the verifier stream past the first mismatch —
//!      the tail beyond position `accepted` cannot influence the
//!      decision (speculative decoding may not leak unverified state);
//!   3. driven in a loop against a deterministic verifier, the
//!      committed token stream equals the verifier's own greedy
//!      rollout exactly, whatever the drafts were (losslessness: the
//!      draft phase can only change *speed*, never *output*).

use qspec::coordinator::greedy_accept;
use qspec::util::check::check;
use qspec::util::prng::Pcg32;

/// Small vocab so random drafts agree with the verifier often enough
/// to exercise multi-token acceptance, not just instant rejection.
const VOCAB: u32 = 8;

fn gen_streams(r: &mut Pcg32) -> (Vec<u32>, Vec<u32>) {
    let g = r.range_inclusive(1, 6) as usize;
    let drafts: Vec<u32> = (0..g).map(|_| r.below(VOCAB)).collect();
    let verify: Vec<u32> = (0..g + 1).map(|_| r.below(VOCAB)).collect();
    (drafts, verify)
}

fn to_i32(v: &[u32]) -> Vec<i32> {
    v.iter().map(|&x| x as i32).collect()
}

/// The longest prefix where draft and verifier agree.
fn matching_prefix(drafts: &[i32], verify: &[i32]) -> usize {
    drafts.iter().zip(verify).take_while(|(d, v)| d == v).count()
}

#[test]
fn accepts_longest_matching_prefix_plus_one_correction() {
    check("accept-prefix", 2000, gen_streams, |(drafts, verify)| {
        let d = to_i32(drafts);
        let v = to_i32(verify);
        let dec = greedy_accept(&d, &v);
        let k = matching_prefix(&d, &v);
        if dec.accepted != k {
            return Err(format!("accepted {} != longest matching prefix {k}", dec.accepted));
        }
        // exactly the prefix plus one token, and that token is the
        // verifier's at the rejection/bonus position
        if dec.committed.len() != k + 1 {
            return Err(format!("committed {} tokens != {k} + 1", dec.committed.len()));
        }
        if dec.committed[..k] != d[..k] {
            return Err("committed prefix != accepted drafts".into());
        }
        if dec.committed[k] != v[k] {
            return Err("correction token is not the verifier's".into());
        }
        Ok(())
    });
}

#[test]
fn never_reads_past_the_first_mismatch() {
    check("accept-no-lookahead", 2000, gen_streams, |(drafts, verify)| {
        let d = to_i32(drafts);
        let v = to_i32(verify);
        let dec = greedy_accept(&d, &v);
        // poison everything after the decision point: the verifier
        // positions beyond `accepted` correspond to unverified state
        // and must not be able to change the outcome
        let mut poisoned = v.clone();
        for t in poisoned.iter_mut().skip(dec.accepted + 1) {
            *t = -999;
        }
        let dec2 = greedy_accept(&d, &poisoned);
        if dec2 != dec {
            return Err(format!("decision depends on the unread tail: {dec:?} vs {dec2:?}"));
        }
        Ok(())
    });
}

/// A deterministic toy verifier: its argmax after any context is a
/// hash of that context. Stands in for "the W4A16 model" so the
/// rollout-equality invariant is checkable without artifacts.
fn verifier_next(context: &[i32]) -> i32 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in context {
        h ^= t as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    (h % VOCAB as u64) as i32
}

#[test]
fn committed_stream_equals_verifier_rollout_regardless_of_drafts() {
    // the losslessness invariant (paper Sec. 3.1): run cycles of
    // arbitrary drafting + greedy_accept against the toy verifier and
    // the committed stream must equal the verifier's own pure-AR
    // rollout of the same length
    check(
        "accept-lossless-rollout",
        300,
        |r: &mut Pcg32| {
            let gamma = r.range_inclusive(1, 5);
            let cycles = r.range_inclusive(1, 8);
            // one u32 per potential draft position: the drafting policy
            // (sometimes the true next token, sometimes garbage)
            let raw: Vec<u32> = (0..(cycles * gamma) as usize).map(|_| r.next_u32()).collect();
            (gamma, raw)
        },
        |(gamma, raw)| {
            let gamma = (*gamma).max(1) as usize;
            let mut committed: Vec<i32> = vec![verifier_next(&[])]; // "prefill" token
            let mut draws = raw.iter().copied().peekable();
            while draws.peek().is_some() && committed.len() <= raw.len() {
                // draft gamma tokens: ~half the time the draft guesses
                // the verifier's true continuation, otherwise garbage
                let mut drafts = Vec::with_capacity(gamma);
                let mut ctx = committed.clone();
                for _ in 0..gamma {
                    let u = match draws.next() {
                        Some(u) => u,
                        None => break,
                    };
                    let truth = verifier_next(&ctx);
                    let t = if u % 2 == 0 { truth } else { (u % VOCAB) as i32 };
                    drafts.push(t);
                    ctx.push(t);
                }
                if drafts.is_empty() {
                    break;
                }
                // the verifier scores prefix + drafts[..j] at position j
                let mut verify = Vec::with_capacity(drafts.len() + 1);
                let mut vctx = committed.clone();
                for &t in &drafts {
                    verify.push(verifier_next(&vctx));
                    vctx.push(t);
                }
                verify.push(verifier_next(&vctx));
                let dec = greedy_accept(&drafts, &verify);
                if dec.committed.is_empty() || dec.committed.len() > drafts.len() + 1 {
                    return Err("commit bounds violated".into());
                }
                committed.extend(dec.committed);
            }
            // pure-AR rollout of the same length must match exactly
            let mut ar = vec![verifier_next(&[])];
            while ar.len() < committed.len() {
                ar.push(verifier_next(&ar));
            }
            if ar != committed {
                return Err(format!(
                    "speculative stream diverged from the verifier's rollout:\n  spec {committed:?}\n  ar   {ar:?}"
                ));
            }
            Ok(())
        },
    );
}
